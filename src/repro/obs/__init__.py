"""repro.obs -- cluster-wide metrics, per-query tracing, ES-style stats.

The monitoring half of the paper's pitch: riding a fulltext-engine
architecture is supposed to buy "robustness, stability, scalability and
monitoring", and PRs 1-5 delivered the first three (sharded + replicated
serving, failover, auto-compaction, durability) while remaining
completely blind at runtime.  This package is the missing observability
plane, threaded through every serving layer at the host-side seams only
-- instrumentation records timestamps *around* jitted program dispatch,
never inside it, so compiled programs and their bit-parity pins are
untouched.

Each piece against its Elasticsearch analogue:

* :mod:`repro.obs.metrics` -- the data behind ``GET _nodes/stats`` and
  ``_cat/thread_pool``: a thread-safe registry of labelled counters,
  gauges, and log-bucketed latency histograms (p50/p90/p99 +
  count/sum), one lock-op per record, globally switchable for the
  overhead-sensitive (``benchmarks/obs_overhead.py`` pins the cost
  < 3% of QPS).
* :mod:`repro.obs.tracing` -- the slow log + tasks API + profile API in
  one object: a sampled per-request :class:`~repro.obs.tracing.Trace`
  follows a query submit -> queue wait -> batch formation -> device
  dispatch, with spill / failover-resubmit / health-transition events
  attached where they happened; ring-buffer retention, dump-on-demand,
  optional ``jax.profiler.TraceAnnotation`` hooks so host spans line up
  with captured device profiles.
* :mod:`repro.obs.stats` -- ``GET _stats`` / ``_cat``: one snapshot
  schema per layer (``BatchedSearchEngine.stats()`` =
  ``_cat/thread_pool`` for one replica group,
  ``ClusterEngine.stats()`` = ``_cluster/stats`` + ``_cat/shards``,
  ``Store.stats()`` = ``_stats/translog`` + commit metadata), with the
  counter-reconciliation contract the smoke run asserts: queries issued
  == sum of per-group completions; one injected failure == one down /
  readmit transition pair.

``launch/serve.py --stats-interval S`` prints one ``_cat``-style line
every S seconds and a full stats + trace dump at exit; ``make
smoke-obs`` runs it on a 4-device cluster with an injected failure and
asserts the counters reconcile.

v2 adds the *why* layer (see ``docs/OBSERVABILITY.md`` for the full
ES mapping):

* :mod:`repro.obs.profile` -- ``_search?profile=true``: a per-query
  :class:`~repro.obs.profile.ProfileNode` phase tree (queue wait ->
  batch form -> encode -> phase-1 -> merge select -> rescore, with
  per-replica-group / per-generation candidate counts and the kernel
  path taken), via ``engine.search(..., profile=True)`` and
  ``ClusterEngine.profile(query)``.
* :mod:`repro.obs.slowlog` -- the search slow log with tail-based
  capture: every request gets a span skeleton; crossing
  ``slow_threshold_s`` (or erroring) promotes it to a full trace +
  profile tree at 100% capture, regardless of head sampling.
* :mod:`repro.obs.compile_watch` -- recompile telemetry: compiles
  counted per (wrapped entry point, abstract-shape signature), compile
  wall-time histogram, and a steady-state guard behind
  ``serve.py --fail-on-recompile``.
* :mod:`repro.obs.export` -- Prometheus text exposition of the
  registry + a JSONL snapshot history ring
  (``serve.py --metrics-file``).

v3 adds the *device* side -- what the programs and arrays actually cost:

* :mod:`repro.obs.device` -- exact index-resident byte accounting per
  shard/segment/quant-table leaf, per section and per device, reconciled
  against ``jax.live_arrays()`` (ES ``_nodes/stats`` store bytes +
  ``_cat/segments``).
* :mod:`repro.obs.cost` -- XLA's static cost model captured at compile
  time (FLOPs / bytes accessed / temp bytes per compiled program),
  attributed to the same :func:`watch_region` stack the compile watch
  uses; joined with measured phase latencies into a live roofline and a
  serve-time check of the fused kernel's byte claim.
* ``cluster_health()`` / ``node_stats()`` in :mod:`repro.obs.stats` --
  ES ``_cluster/health`` (green/yellow/red reconciled exactly against
  the HealthMap transition ledger) and ``_nodes/stats``.
* :mod:`repro.obs.diagnostics` -- the one-call support-diagnostics
  bundle (``serve.py --diagnostics-on-exit``, auto-dumped on failover
  and ``--kill-and-recover``).
"""

from .compile_watch import CompileWatch, active_watch, watch_region
from .cost import (CostTable, ensure_cost_capture, kernel_byte_ratio,
                   missing_cost_regions, roofline, verify_kernel_claim)
from .device import (device_bytes, format_device_line,
                     resident_leaf_entries)
from .diagnostics import (BUNDLE_SECTIONS, diagnostics_bundle,
                          write_diagnostics)
from .export import (MetricsExporter, device_gauges, health_gauges,
                     prometheus_text)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      default_registry)
from .profile import ProfileNode, format_profile_tree, profile_from_trace
from .slowlog import SlowLog, start_request_trace
from .stats import (cluster_health, cluster_stats, engine_stats,
                    format_health_line, format_segments_line,
                    format_stats_line, index_stats, node_stats,
                    store_stats)
from .tracing import NULL_TRACE, Span, Trace, Tracer, annotation

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "default_registry",
    "Span", "Trace", "Tracer", "NULL_TRACE", "annotation",
    "index_stats", "engine_stats", "cluster_stats", "store_stats",
    "cluster_health", "node_stats",
    "format_stats_line", "format_segments_line", "format_health_line",
    "ProfileNode", "format_profile_tree", "profile_from_trace",
    "SlowLog", "start_request_trace",
    "CompileWatch", "active_watch", "watch_region",
    "MetricsExporter", "prometheus_text", "health_gauges", "device_gauges",
    "device_bytes", "format_device_line", "resident_leaf_entries",
    "CostTable", "ensure_cost_capture", "missing_cost_regions",
    "roofline", "kernel_byte_ratio", "verify_kernel_claim",
    "BUNDLE_SECTIONS", "diagnostics_bundle", "write_diagnostics",
]
