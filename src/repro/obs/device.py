"""Device-resident index memory accounting (what is holding HBM, where).

The serving index is a pytree of device arrays -- base shards, append
buffers, sealed segments, posting tables, lazily derived int8 quant
tables -- and nothing in the obs plane could answer the first question
an operator asks when a device fills up: *which part of the index owns
those bytes, and on which device do they live?*  ES answers it with
``_nodes/stats`` (``indices.store.size_in_bytes`` per node) and
``_cat/segments`` (bytes per segment); this module is that ledger:

* :func:`device_bytes` walks every resident leaf an index holds --
  including the quant-table caches that are NOT pytree children -- and
  returns exact byte totals per leaf, per section (``base`` / ``active``
  / ``segments`` / ``quant``), and per physical device (attributed
  through each array's ``addressable_shards``, so a leaf replicated
  across the ``replica`` mesh axis is charged once per device that
  holds a copy, which is what the hardware actually pays).
* the accounting is *computed*, never measured: byte counts come from
  leaf shapes and dtypes (``arr.nbytes`` and shard ``data.nbytes``), so
  the walk costs no device synchronisation and is safe to poll from the
  serving path.  A ``reconciliation`` section cross-checks it against
  the process truth where the backend exposes it: every leaf is looked
  up in ``jax.live_arrays()`` (an index leaf that is not live would be
  an accounting bug) and the process-wide live-array total is reported
  next to the index's share, so ``stats()`` can answer "what ELSE is
  holding HBM".

Indexes expose their leaves via a ``resident_leaves()`` iterator of
``(path, section, array)`` triples (:meth:`repro.dist.shard_index.
ShardedVectorIndex.resident_leaves` includes the quant caches); anything
else -- plain :class:`~repro.core.VectorIndex`, test doubles -- falls
back to a generic pytree walk.  Wrapper indexes (``_FailpointIndex``,
``DurableIndex``) proxy attribute access, so the walk sees through them.

Aliased leaves (two paths reaching the SAME array object -- e.g. a
cache carried across a ``dataclasses.replace``) are counted once and
reported in ``aliased_leaves``: totals are physical bytes, not a sum
over views.
"""

from __future__ import annotations

from typing import Iterator, Tuple

__all__ = ["device_bytes", "resident_leaf_entries", "format_device_line"]

_MB = 1024.0 * 1024.0


def _fallback_leaves(index) -> Iterator[Tuple[str, str, object]]:
    """Generic pytree walk for indexes without ``resident_leaves()``:
    the leaf path comes from the tree structure, the section from the
    top-level field name (``vectors``/``codes``/``postings`` for a flat
    :class:`~repro.core.VectorIndex`)."""
    import jax

    for path, leaf in jax.tree_util.tree_flatten_with_path(index)[0]:
        name = jax.tree_util.keystr(path).lstrip(".")
        section = name.split(".")[0].split("[")[0] or "index"
        yield name, section, leaf


def resident_leaf_entries(index) -> Iterator[Tuple[str, str, object]]:
    """``(path, section, array)`` for every device-resident leaf of
    ``index`` -- its own ``resident_leaves()`` when it has one (the
    sharded index's includes the non-pytree quant caches), else the
    generic pytree walk."""
    leaves = getattr(index, "resident_leaves", None)
    if leaves is not None:
        yield from leaves()
    else:
        yield from _fallback_leaves(index)


def device_bytes(index, *, reconcile: bool = True) -> dict:
    """Exact index-resident byte accounting: per leaf, per section, per
    device.

    Returns a JSON-ready dict::

        {"total_bytes": int,          # sum of unique leaf nbytes
         "sections": {section: bytes},
         "leaves": [{"path", "section", "shape", "dtype", "nbytes"}],
         "per_device": {device: bytes},   # physical residency (replicas
                                          #  charged per holding device)
         "n_leaves": int, "aliased_leaves": int,
         "reconciliation": {...}}         # vs jax.live_arrays()

    ``total_bytes`` is the logical index size (shape x dtype per unique
    leaf -- what the byte-accounting tests pin against leaf ``nbytes``);
    ``per_device`` sums each leaf's ``addressable_shards``, so its total
    EXCEEDS ``total_bytes`` exactly by the replication factor of
    replicated leaves.  ``reconcile=False`` skips the
    ``jax.live_arrays()`` sweep (the whole-process walk is the only
    non-O(index) part -- pollers on a hot path may skip it).
    """
    leaves = []
    sections: dict = {}
    per_device: dict = {}
    seen: dict = {}
    total = 0
    aliased = 0
    for path, section, arr in resident_leaf_entries(index):
        if arr is None:
            continue
        nbytes = getattr(arr, "nbytes", None)
        if nbytes is None:
            continue
        if id(arr) in seen:
            aliased += 1
            continue
        seen[id(arr)] = arr          # keep the ref: id() must stay unique
        nbytes = int(nbytes)
        total += nbytes
        sections[section] = sections.get(section, 0) + nbytes
        leaves.append({
            "path": path,
            "section": section,
            "shape": tuple(int(d) for d in getattr(arr, "shape", ())),
            "dtype": str(getattr(arr, "dtype", "?")),
            "nbytes": nbytes,
        })
        shards = getattr(arr, "addressable_shards", None)
        if shards is not None:
            try:
                for sh in shards:
                    dev = str(sh.device)
                    per_device[dev] = (per_device.get(dev, 0)
                                       + int(sh.data.nbytes))
            except Exception:  # pragma: no cover - exotic backends
                pass
    out = {
        "total_bytes": total,
        "sections": dict(sorted(sections.items())),
        "leaves": leaves,
        "per_device": dict(sorted(per_device.items())),
        "n_leaves": len(leaves),
        "aliased_leaves": aliased,
    }
    if reconcile:
        import jax

        live = jax.live_arrays()
        live_ids = {id(a) for a in live}
        accounted = sum(
            entry["nbytes"] for entry, arr in zip(leaves, seen.values())
            if id(arr) in live_ids)
        out["reconciliation"] = {
            # index leaves found among the backend's live arrays -- every
            # jax leaf must reconcile (accounted == jax leaf bytes)
            "accounted_bytes": int(accounted),
            "live_leaves": sum(1 for a in seen.values()
                               if id(a) in live_ids),
            # the process truth: everything live on the backend, index or
            # not -- the "what else is holding HBM" number
            "process_live_bytes": int(sum(a.nbytes for a in live)),
            "process_live_arrays": len(live),
            "device_resident_bytes": int(sum(per_device.values())),
        }
    return out


def format_device_line(dev: dict) -> str:
    """One ``_cat``-style line from a :func:`device_bytes` dict: total,
    per-section split, device count -- the glanceable "what is holding
    HBM" view."""
    parts = [f"device_bytes total={dev['total_bytes'] / _MB:.2f}MB"]
    for section, b in dev["sections"].items():
        parts.append(f"{section}={b / _MB:.2f}MB")
    parts.append(f"leaves={dev['n_leaves']}")
    if dev.get("per_device"):
        parts.append(f"devices={len(dev['per_device'])}")
    return " ".join(parts)
