"""ES ``_search?profile=true``-style per-query execution profiles.

A :class:`ProfileNode` tree is the answer to *why was THIS query slow*:
one node per serving phase -- queue wait, batch formation, then the
dispatch subtree the index itself annotates (encode, phase-1, merge
select, final rescore) with per-replica-group and per-generation child
nodes carrying candidate counts -- plus the config that shaped the work
(engine, kernel path taken, page/k, merge transport).

Collection discipline (the same contract as :mod:`repro.obs.metrics` /
:mod:`repro.obs.tracing`): every timestamp is host-side, taken *around*
jitted program dispatch.  In profile mode the phase boundaries are fenced
with ``jax.block_until_ready`` so a phase's wall time is attributable to
that phase -- blocking changes WHEN the host observes values, never the
values themselves, so bit-parity with profiling ON is pinned.

Reconciliation is part of the schema: a root's ``duration_s`` and its
top-level children derive from SHARED clock reads in the batcher (the
end of ``queue_wait`` IS the start of ``batch_form``), so the phases
tile the total exactly (float addition error only) -- asserted by
``serve.py --profile`` and the ``make smoke-profile`` run.

Entry points: ``BatchedSearchEngine.search(..., profile=True)`` /
``submit(..., profile=True)`` resolve to ``(ids, scores, profile_dict)``;
``ClusterEngine.profile(query)`` adds the routing phase on top.
:func:`format_profile_tree` renders the dict ``_cat``-style;
:func:`profile_from_trace` derives a profile view from a finished
:class:`~repro.obs.tracing.Trace` (the slow log's promotion path).
"""

from __future__ import annotations

from typing import List, Optional

__all__ = ["ProfileNode", "format_profile_tree", "profile_from_trace"]


class ProfileNode:
    """One phase of a profiled request.  ``duration_s`` is host wall
    time (None for structural nodes that only carry attrs, e.g. a
    per-generation candidate-count child); ``children`` hold sub-phases,
    as nodes or already-serialized dicts (a cluster root adopts the
    engine subtree in dict form)."""

    __slots__ = ("name", "duration_s", "attrs", "children")

    def __init__(self, name: str, duration_s: Optional[float] = None,
                 **attrs):
        self.name = name
        self.duration_s = duration_s
        self.attrs = attrs
        self.children: List = []

    def child(self, name: str, duration_s: Optional[float] = None,
              **attrs) -> "ProfileNode":
        node = ProfileNode(name, duration_s, **attrs)
        self.children.append(node)
        return node

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "duration_s": self.duration_s,
            "attrs": dict(self.attrs),
            "children": [c.to_dict() if isinstance(c, ProfileNode) else c
                         for c in self.children],
        }


def _fmt_attrs(attrs: dict) -> str:
    return " ".join(f"{k}={v}" for k, v in attrs.items())


def format_profile_tree(profile) -> str:
    """Render a profile dict (or node) as an indented ``_cat``-style
    tree: one line per phase with wall time, percent of the root total,
    and the phase's attrs.  Durationless structural nodes render ``-``.
    """
    if isinstance(profile, ProfileNode):
        profile = profile.to_dict()
    total = profile.get("duration_s")
    lines: List[str] = []

    def emit(node: dict, prefix: str, branch: str, kid_prefix: str):
        dur = node.get("duration_s")
        dtxt = "        -" if dur is None else f"{dur * 1e3:7.3f}ms"
        pct = ""
        if dur is not None and total:
            pct = f" {100.0 * dur / total:5.1f}%"
        attrs = _fmt_attrs(node.get("attrs", {}))
        name = str(node.get("name", "?"))
        pad = max(1, 24 - len(prefix + branch + name))
        lines.append(f"{prefix}{branch}{name}{' ' * pad}{dtxt}{pct}"
                     + (f"  {attrs}" if attrs else ""))
        kids = node.get("children", [])
        for i, c in enumerate(kids):
            last = i == len(kids) - 1
            emit(c, kid_prefix, "`- " if last else "|- ",
                 kid_prefix + ("   " if last else "|  "))

    emit(profile, "", "", "")
    return "\n".join(lines)


def profile_from_trace(trace: dict) -> dict:
    """A profile tree derived from a finished trace dict (the slow log's
    promotion path: every request carries a span skeleton, and a slow or
    failed one is promoted to this view).  Spans become phase children;
    span events become durationless grandchildren, so a failover's
    spill/resubmit history survives into the rendered tree."""
    root = ProfileNode(trace.get("name", "query"), **trace.get("attrs", {}))
    t0, t1 = trace.get("t0"), trace.get("t1")
    if t0 is not None and t1 is not None:
        root.duration_s = t1 - t0
    for s in trace.get("spans", ()):
        node = root.child(s["name"], s.get("duration_s"),
                          **s.get("attrs", {}))
        for ev in s.get("events", ()):
            node.child(f"event:{ev['name']}", **ev.get("attrs", {}))
    return root.to_dict()
