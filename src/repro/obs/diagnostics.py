"""One-call support-diagnostics bundle (the ES diagnostics tarball).

When an ES cluster misbehaves, support asks for one artifact: the
diagnostics bundle -- every ``_stats``/``_cluster/health``/
``_nodes/stats`` surface plus recent logs, captured at one instant,
parseable offline.  :func:`diagnostics_bundle` is that artifact for this
stack: a single JSON document snapshotting every obs surface the repo
has grown --

========================  ==============================================
section                   contents (ES analogue)
========================  ==============================================
``meta``                  wall/monotonic timestamps, dump reason,
                          backend + device count
``stats``                 ``engine.stats()`` rollup (``_stats``)
``health``                :func:`~repro.obs.stats.cluster_health`
                          (``_cluster/health``; None for a single
                          engine -- no cluster state to report)
``nodes``                 :func:`~repro.obs.stats.node_stats`
                          (``_nodes/stats``)
``device``                per-group :func:`~repro.obs.device.
                          device_bytes` leaf tables (``_cat/segments``
                          bytes view)
``cost``                  static FLOPs/bytes rows per watch region
                          (:class:`~repro.obs.cost.CostTable`)
``compile``               compile-watch counters + steady-state events
``slowlog``               the slow-log ring, NOT cleared (dumping
                          diagnostics must not eat the evidence)
``traces``                the tracer ring, when sampling is on
``metrics``               full registry snapshot
``metrics_history``       the exporter's recent collection ring, when
                          an exporter is polling
========================  ==============================================

Every section key is ALWAYS present (None/empty when the surface is not
wired), so consumers -- and ``make smoke-health`` -- can assert bundle
completeness structurally.  :func:`write_diagnostics` wraps it in a
timestamped file; ``serve.py --diagnostics-on-exit DIR`` dumps one at
exit and automatically on failover and ``--kill-and-recover``.
"""

from __future__ import annotations

import json
import os
import time
from typing import Optional

__all__ = ["diagnostics_bundle", "write_diagnostics", "BUNDLE_SECTIONS"]

BUNDLE_SECTIONS = ("meta", "stats", "health", "nodes", "device", "cost",
                   "compile", "slowlog", "traces", "metrics",
                   "metrics_history")


def _jsonable(obj):
    """``json.dump`` default: numpy scalars/arrays and sets degrade to
    plain python; anything else degrades to ``repr`` rather than
    failing the bundle (a diagnostics dump must not raise over one
    exotic value)."""
    try:
        import numpy as np

        if isinstance(obj, np.ndarray):
            return obj.tolist()
        if isinstance(obj, np.generic):
            return obj.item()
    except Exception:
        pass
    if isinstance(obj, (set, frozenset)):
        return sorted(obj)
    return repr(obj)


def diagnostics_bundle(engine, *, exporter=None,
                       reason: Optional[str] = None) -> dict:
    """Snapshot every obs surface of ``engine`` (a
    ``BatchedSearchEngine`` or ``ClusterEngine``) into one JSON-ready
    dict with the :data:`BUNDLE_SECTIONS` keys.  ``exporter`` (a
    :class:`~repro.obs.export.MetricsExporter`) contributes its recent
    collection history when provided; ``reason`` records why the bundle
    was cut (``"exit"``, ``"failover"``, ``"kill-and-recover"``)."""
    from repro.obs.device import device_bytes
    from repro.obs.stats import cluster_health, node_stats

    meta = {
        "t_wall": time.time(),
        "t_monotonic": time.monotonic(),
        "reason": reason,
    }
    try:
        import jax

        meta["backend"] = jax.default_backend()
        meta["n_devices"] = jax.device_count()
    except Exception:
        pass

    batchers = getattr(engine, "batchers", None)
    if batchers is not None:
        health = cluster_health(engine)
        device = {str(g): device_bytes(b.index)
                  for g, b in enumerate(batchers)}
    else:
        health = None
        device = {"0": device_bytes(engine.index)}

    watch = getattr(engine, "compile_watch", None)
    slowlog = getattr(engine, "slowlog", None)
    tracer = getattr(engine, "tracer", None)

    return {
        "meta": meta,
        "stats": engine.stats(),
        "health": health,
        "nodes": node_stats(engine),
        "device": device,
        "cost": watch.costs.stats() if watch is not None else None,
        "compile": watch.stats() if watch is not None else None,
        "slowlog": (None if slowlog is None
                    else {"entries": slowlog.dump(clear=False),
                          "stats": slowlog.stats()}),
        "traces": (None if tracer is None
                   else {"entries": tracer.dump(),
                         "stats": tracer.stats()}),
        "metrics": engine.metrics.snapshot(),
        "metrics_history": (exporter.history()
                            if exporter is not None else []),
    }


def write_diagnostics(engine, directory: str, *, exporter=None,
                      reason: Optional[str] = None) -> str:
    """Cut a bundle and write it as ``diagnostics-<utc>-<reason>.json``
    under ``directory`` (created if needed); returns the file path.
    File names carry a monotonic disambiguator so two dumps in the same
    second (failover then exit) never clobber each other."""
    bundle = diagnostics_bundle(engine, exporter=exporter, reason=reason)
    os.makedirs(directory, exist_ok=True)
    stamp = time.strftime("%Y%m%dT%H%M%S", time.gmtime())
    tag = f"{time.monotonic_ns() % 1_000_000:06d}"
    path = os.path.join(
        directory,
        f"diagnostics-{stamp}-{tag}-{reason or 'manual'}.json")
    with open(path, "w") as f:
        json.dump(bundle, f, indent=1, default=_jsonable)
    return path
