"""Static cost attribution at compile time: FLOPs/HBM-bytes per region.

The compile watch (PR 9) tells you *that* a region compiled; this module
records *what* it compiled: at the moment XLA hands back an executable,
the backend's own static cost model (``Compiled.cost_analysis()`` --
FLOPs and bytes accessed) and memory analysis
(``get_compiled_memory_stats()`` -- argument/output/temp/code bytes) are
captured and attributed to the innermost active :func:`~repro.obs.
compile_watch.watch_region`, using the SAME thread-local attribution
rule as compile counting.  That identity is the contract: every region
the watch counts a compile for must also own a cost row (checked by
:func:`missing_cost_regions` -- "no unattributed serving compiles").

The capture seam is a process-wide wrap of JAX's single compile
entry point (``jax._src.compiler.compile_or_get_cached``), installed
lazily by the first enabled :class:`~repro.obs.compile_watch.
CompileWatch`; it adds two dict lookups per *compile* (never per
dispatch), so steady-state serving cost is zero.

What the rows buy:

* a live roofline view (:func:`roofline`): static bytes/FLOPs joined
  with measured per-phase wall time from ``profile.py`` gives achieved
  GB/s and GFLOP/s per phase -- the ES hot-threads question ("is this
  phase bandwidth-bound or overhead-bound?") answered from telemetry
  already on hand;
* a serve-time check of PR 8's headline claim (:func:`kernel_byte_
  ratio` / :func:`verify_kernel_claim`): the fused phase-1 program must
  access fewer bytes than the composed pipeline *in the program XLA
  actually compiled for the serving index*, reconciled against the
  committed ``BENCH_kernel_scale.json`` byte-model ratio.
"""

from __future__ import annotations

import json
import threading
from typing import Dict, List, Optional, Tuple

__all__ = [
    "CostTable", "ensure_cost_capture", "cost_capture_installed",
    "missing_cost_regions", "roofline", "kernel_byte_ratio",
    "verify_kernel_claim",
]

_install_lock = threading.Lock()
_installed = False

# engine names as they appear in dispatch sigs, by phase-1 lowering
_FUSED_ENGINES = ("fused_int8", "fused")
_COMPOSED_ENGINES = ("codes", "postings", "onehot")


# --------------------------------------------------------------- the table
class CostTable:
    """Per-(region, signature, program) static cost rows.

    One row per distinct compiled program reached from a region; repeat
    compiles of the same key bump ``compiles`` and refresh the numbers
    (XLA's estimate for an identical program is stable, so last-write
    is as good as first)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._rows: Dict[Tuple[str, Tuple, str], dict] = {}

    def record(self, region: str, sig: Tuple, program: str,
               cost: Optional[dict], memory: Optional[dict]) -> None:
        key = (region, tuple(str(s) for s in sig), program)
        with self._lock:
            row = self._rows.get(key)
            if row is None:
                row = self._rows[key] = {
                    "region": region,
                    "sig": list(key[1]),
                    "program": program,
                    "compiles": 0,
                }
            row["compiles"] += 1
            if cost:
                row["flops"] = float(cost.get("flops", 0.0))
                row["bytes_accessed"] = float(
                    cost.get("bytes accessed", 0.0))
                if "transcendentals" in cost:
                    row["transcendentals"] = float(cost["transcendentals"])
            if memory:
                row.update(memory)

    # ------------------------------------------------------------- queries
    def rows(self) -> List[dict]:
        with self._lock:
            return [dict(r) for r in self._rows.values()]

    def regions(self) -> set:
        with self._lock:
            return {region for region, _sig, _prog in self._rows}

    def stats(self) -> dict:
        """Stats-section dict: row count plus per-region rollups (program
        count, compiles, summed FLOPs/bytes, peak temp bytes) and the
        raw rows for the diagnostics bundle."""
        rows = self.rows()
        by_region: Dict[str, dict] = {}
        for r in rows:
            agg = by_region.setdefault(r["region"], {
                "programs": 0, "compiles": 0, "flops": 0.0,
                "bytes_accessed": 0.0, "peak_temp_bytes": 0,
            })
            agg["programs"] += 1
            agg["compiles"] += r["compiles"]
            agg["flops"] += r.get("flops", 0.0)
            agg["bytes_accessed"] += r.get("bytes_accessed", 0.0)
            agg["peak_temp_bytes"] = max(agg["peak_temp_bytes"],
                                         int(r.get("temp_bytes", 0)))
        return {"n_rows": len(rows), "by_region": by_region, "rows": rows}


# ------------------------------------------------------------ capture seam
def _module_name(computation) -> str:
    """The compiled module's symbol name (``jit__query_phase``-style)
    without serializing the module text."""
    try:
        attr = computation.operation.attributes["sym_name"]
        name = getattr(attr, "value", None)
        if name:
            return str(name)
        return str(attr).strip('"')
    except Exception:
        return "<module>"


def _executable_costs(executable):
    """(cost dict, memory dict) from a LoadedExecutable, tolerating the
    backends that expose neither (both become None, the row still
    counts the compile)."""
    cost = None
    try:
        c = executable.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0] if c else None
        if isinstance(c, dict):
            cost = c
    except Exception:
        pass
    memory = None
    try:
        ms = executable.get_compiled_memory_stats()
        memory = {
            "argument_bytes": int(ms.argument_size_in_bytes),
            "output_bytes": int(ms.output_size_in_bytes),
            "temp_bytes": int(ms.temp_size_in_bytes),
            "code_bytes": int(ms.generated_code_size_in_bytes),
        }
    except Exception:
        pass
    return cost, memory


def _attribute(computation, executable) -> None:
    from repro.obs import compile_watch as cw

    stack = getattr(cw._TLS, "stack", None)
    if stack:
        watch, region, sig = stack[-1]
    else:
        watch, region, sig = cw.active_watch(), cw._UNATTRIBUTED, ()
    cost, memory = _executable_costs(executable)
    watch.costs.record(region, sig, _module_name(computation), cost, memory)


def ensure_cost_capture() -> None:
    """Install the (one, process-wide) compile-time cost hook: wrap
    ``jax._src.compiler.compile_or_get_cached`` -- the single funnel
    every jit compile goes through -- and attribute each returned
    executable's cost/memory analysis to the active watch region.
    The wrap MUST be ``*args`` -- the funnel takes six positional
    parameters (``pgle_profiler`` is passed positionally) and private
    signatures drift between jax versions."""
    global _installed
    if _installed:
        return
    with _install_lock:
        if _installed:
            return
        try:
            from jax._src import compiler as _compiler

            orig = _compiler.compile_or_get_cached

            def _wrap(*args, **kwargs):
                executable = orig(*args, **kwargs)
                try:
                    _attribute(args[1], executable)
                except Exception:   # never perturb compilation itself
                    pass
                return executable

            _wrap.__wrapped__ = orig
            _compiler.compile_or_get_cached = _wrap
        except Exception:  # pragma: no cover - jax always present in-repo
            pass
        _installed = True


def cost_capture_installed() -> bool:
    return _installed


# ------------------------------------------------------------- derived views
def missing_cost_regions(watch) -> List[str]:
    """Regions the watch counted a compile for that own NO cost row --
    the "no unattributed serving compiles" contract; empty when every
    compiled region is accounted.  (Cost rows are a superset of counted
    compiles: the hook also fires on compilation-cache hits.)"""
    compiled = set(watch.stats()["by_function"])
    compiled.discard("<unattributed>")
    return sorted(compiled - watch.costs.regions())


def roofline(watch, phase_seconds: Dict[str, float]) -> List[dict]:
    """Join static per-region costs with measured per-phase wall time
    into achieved-bandwidth rows.

    ``phase_seconds`` maps region name -> measured seconds for ONE
    execution of that region (e.g. a per-phase mean from
    ``profile.profile_search``).  For regions that compiled several
    programs (shape growth, engine variants) the row with the most
    bytes accessed is taken as the phase's main program; ``programs``
    reports how many were folded away."""
    by_region: Dict[str, List[dict]] = {}
    for r in watch.costs.rows():
        by_region.setdefault(r["region"], []).append(r)
    out = []
    for region, seconds in sorted(phase_seconds.items()):
        rows = by_region.get(region)
        if not rows or seconds <= 0:
            continue
        main = max(rows, key=lambda r: r.get("bytes_accessed", 0.0))
        flops = main.get("flops", 0.0)
        nbytes = main.get("bytes_accessed", 0.0)
        out.append({
            "region": region,
            "program": main["program"],
            "programs": len(rows),
            "measured_s": float(seconds),
            "flops": flops,
            "bytes_accessed": nbytes,
            "achieved_gflops": flops / seconds / 1e9,
            "achieved_gbps": nbytes / seconds / 1e9,
            # bytes per FLOP > ~1 reads memory-bound on any current part
            "bytes_per_flop": nbytes / flops if flops else None,
        })
    return out


def _phase1_rows_by_variant(watch, region: str = "search.query_phase"):
    fused: List[dict] = []
    composed: List[dict] = []
    for r in watch.costs.rows():
        if r["region"] != region or not r.get("bytes_accessed"):
            continue
        sig = r.get("sig", ())
        if any(e in sig for e in _FUSED_ENGINES):
            fused.append(r)
        elif any(e in sig for e in _COMPOSED_ENGINES):
            composed.append(r)
    return fused, composed


def kernel_byte_ratio(watch) -> Optional[dict]:
    """Fused-vs-composed byte ratio of the phase-1 programs XLA actually
    compiled for the serving index: max bytes-accessed among fused rows
    over max among composed rows (max = the largest shapes reached,
    which both variants reach together).  None until both variants have
    compiled under ``search.query_phase``."""
    fused, composed = _phase1_rows_by_variant(watch)
    if not fused or not composed:
        return None
    fb = max(r["bytes_accessed"] for r in fused)
    cb = max(r["bytes_accessed"] for r in composed)
    return {
        "fused_bytes": fb,
        "composed_bytes": cb,
        "ratio": fb / cb if cb else None,
        "fused_rows": len(fused),
        "composed_rows": len(composed),
    }


def verify_kernel_claim(watch, artifact_path: str,
                        slack: float = 1.5) -> dict:
    """Assert PR 8's ``BENCH_kernel_scale`` bandwidth claim against the
    live compiled programs: the fused phase-1 program must access fewer
    bytes than the composed pipeline (ratio < 1), and the live ratio
    must not exceed the committed byte-model claim by more than
    ``slack``x (the hand byte model and XLA's cost model count slightly
    different things; the *claim* is the direction and rough magnitude).
    Returns ``{"live": ..., "claimed_ratio": ...}``; raises
    ``AssertionError`` when the claim fails to hold live."""
    live = kernel_byte_ratio(watch)
    if live is None:
        raise AssertionError(
            "kernel claim check needs both a fused and a composed "
            "phase-1 compile under search.query_phase")
    with open(artifact_path) as f:
        bench = json.load(f)
    rows = bench.get("rows", [])
    top = max((r.get("n_docs", 0) for r in rows), default=0)
    hbm = {r["variant"]: r["hbm_bytes"] for r in rows
           if r.get("n_docs") == top and "hbm_bytes" in r}
    claimed = None
    if "fused" in hbm and "composed" in hbm and hbm["composed"]:
        claimed = hbm["fused"] / hbm["composed"]
    assert live["ratio"] is not None and live["ratio"] < 1.0, (
        f"fused phase-1 accesses MORE bytes than composed live: "
        f"{live['fused_bytes']:.3g} vs {live['composed_bytes']:.3g}")
    if claimed is not None:
        assert live["ratio"] <= claimed * slack, (
            f"live fused/composed byte ratio {live['ratio']:.3f} exceeds "
            f"the committed claim {claimed:.3f} by more than {slack}x")
    return {"live": live, "claimed_ratio": claimed, "n_docs": top}
