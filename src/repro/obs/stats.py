"""ES ``_stats``/``_cat``-style snapshot assembly.

One function per serving layer, each returning a plain nested dict (JSON-
ready, the shape ES returns from ``GET <index>/_stats`` / ``_cat``
endpoints).  The layer classes expose them as methods --
``BatchedSearchEngine.stats()``, ``ClusterEngine.stats()``,
``Store.stats()`` -- but the assembly lives here so the serving classes
carry no formatting code and the obs package owns the schema.

What maps where:

* :func:`index_stats` -- ES ``_stats/docs,segments``: doc counts,
  per-generation segment rows/tombstones/deleted ratios (the tiered
  merge policy's inputs), active-buffer occupancy, per-shard tombstones,
  tombstone ratio (the full-compact trigger).
  :func:`format_segments_line` renders it ``_cat/segments``-style.
* :func:`engine_stats` -- ES ``_cat/thread_pool`` + node stats for one
  replica-group batcher: queue depth, in-flight, batch occupancy,
  queue-wait and dispatch-latency histograms, request counters.
* :func:`cluster_stats` -- the cluster-level rollup (``_cluster/stats``
  + ``_cat/shards``): per-group engine stats + health state, routing
  counters (spills, failover resubmits, per-group completions),
  health-transition counters, maintenance + store sections when wired.
* :func:`store_stats` -- ES ``_stats/translog`` + commit metadata:
  translog seqno/generation/bytes, newest commit generation/seq,
  commit + recovery counters and timings.

Counter reconciliation is part of the schema contract (pinned by
tests/test_obs.py and the ``make smoke-obs`` run): queries issued ==
``cluster.requests.completed`` == sum over groups of
``cluster.requests.group_completed``; one injected group failure ==
one ``failover.resubmits`` increment (sequential traffic) == one
``health.down_transitions`` + one readmit once healed.
"""

from __future__ import annotations

import math
import os
from typing import Optional

__all__ = ["index_stats", "engine_stats", "cluster_stats", "store_stats",
           "cluster_health", "node_stats", "format_stats_line",
           "format_segments_line", "format_health_line"]


def _hist(registry, name: str, **labels) -> dict:
    return registry.histogram(name, **labels).snapshot()


def _kernel_mix(registry, labels: dict) -> dict:
    """Dispatch counts per phase-1 path for ONE batcher, parsed from
    the ``engine.kernel_path`` series (labelled ``engine=<name>`` plus
    the batcher's own labels).  A fleet registry holds every batcher's
    series; filtering on the non-engine labels keeps each group's mix
    its own."""
    want = {k: str(v) for k, v in labels.items()}
    out: dict = {}
    for label_str, v in registry.series("engine.kernel_path").items():
        kv = dict(part.split("=", 1) for part in label_str.split(",") if part)
        eng = kv.pop("engine", None)
        if eng is None or kv != want:
            continue
        out[eng] = out.get(eng, 0) + v
    return out


def _compile_stats(watch) -> dict:
    """The compile-watch section, without the (possibly long) event
    list -- stats lines want the totals; ``watch.stats()`` has the rest.
    """
    s = watch.stats()
    out = {k: s[k] for k in ("compiles_total", "compiles_steady_state",
                             "steady", "signatures", "by_function")}
    # the static-cost rollup (FLOPs/bytes per region) rides the same
    # section; raw rows stay on watch.costs for the diagnostics bundle
    cost = watch.costs.stats()
    out["cost"] = {"n_rows": cost["n_rows"], "by_region": cost["by_region"]}
    return out


def index_stats(index) -> dict:
    """Docs/segments section for any served index (plain VectorIndex
    reports what it has; sharded/durable indexes report the full ES
    segment story).  Attribute-guarded: works through _FailpointIndex
    and DurableIndex wrappers via their attribute proxying."""
    out = {"n_ids": int(getattr(index, "n_ids", getattr(index, "n_docs", 0)))}
    for name in ("n_docs", "n_shards", "n_replicas", "n_appended",
                 "seg_capacity"):
        v = getattr(index, name, None)
        if v is not None:
            out[name] = int(v)
    tombs = getattr(index, "shard_tombstones", None)
    if tombs is not None:
        out["shard_tombstones"] = tuple(int(t) for t in tombs)
        out["n_tombstones"] = int(getattr(index, "n_tombstones", sum(tombs)))
        out["tombstone_ratio"] = float(getattr(index, "tombstone_ratio", 0.0))
    segs = getattr(index, "segments", None)
    if segs is not None:
        # the _cat/segments view: per-generation doc/tombstone counts --
        # the per-segment deleted ratios are what the tiered merge policy
        # consults (the whole-index tombstone_ratio can't see which
        # generation the deletes hit)
        out["n_segments"] = len(segs)
        out["segments"] = [
            {"rows": int(s.n_rows), "width": int(s.width),
             "tombstones": int(s.tombstones),
             "deleted_ratio": float(s.deleted_ratio)}
            for s in segs]
        for name in ("n_active", "seg_base", "active_tombstones",
                     "n_reclaimed"):
            v = getattr(index, name, None)
            if v is not None:
                out[name] = int(v)
    seq = getattr(index, "translog_seq", None)
    if seq is not None:
        out["translog_seq"] = int(seq)
    return out


def engine_stats(engine) -> dict:
    """One batcher's thread-pool view: queue/in-flight depths, request
    counters, occupancy + latency histograms, the served index's doc
    stats."""
    reg, labels = engine.metrics, engine._metric_labels
    with engine._lock:
        queue_depth = len(engine._queue)
        inflight = engine._inflight
        index = engine.index
    # the full dispatch mix, not just this batcher's configured engine:
    # a batcher reconfigured mid-life (or sharing a registry with its
    # past self) reports every path it ever took, zero-seeded with the
    # current one so the mix is never empty
    mix = _kernel_mix(reg, labels)
    mix.setdefault(engine.engine, 0)
    out = {
        "queue_depth": queue_depth,
        "in_flight": inflight,
        "pending": queue_depth + inflight,
        "batch_size": engine.batch_size,
        "max_wait_s": engine.max_wait_s,
        "requests": {
            "submitted": reg.value("engine.requests.submitted", **labels),
            "completed": reg.value("engine.requests.completed", **labels),
            "failed": reg.value("engine.requests.failed", **labels),
        },
        "batches": _hist(reg, "engine.batch.occupancy", **labels),
        "queue_wait_s": _hist(reg, "engine.queue.wait_s", **labels),
        "dispatch_latency_s": _hist(reg, "engine.dispatch.latency_s",
                                    **labels),
        "ingest": {
            "added_docs": reg.value("engine.ingest.added_docs", **labels),
            "delete_ops": reg.value("engine.ingest.delete_ops", **labels),
            "swaps": reg.value("engine.swaps", **labels),
        },
        # dispatches by phase-1 path (labelled by engine name) -- the
        # fused-kernel rollout gauge: a mixed fleet shows its
        # fused/composed split here
        "kernel_path": mix,
        "index": index_stats(index),
    }
    slowlog = getattr(engine, "slowlog", None)
    if slowlog is not None:
        out["slowlog"] = slowlog.stats()
    watch = getattr(engine, "compile_watch", None)
    if watch is not None:
        out["compile"] = _compile_stats(watch)
    return out


def _maintenance_stats(daemon) -> dict:
    return {
        "compactions": daemon.compactions,
        "merges": daemon.merges,
        "merges_by_group": daemon.metrics.series("maintenance.merges"),
        "reclaimed_by_group": daemon.metrics.series(
            "maintenance.merge.reclaimed"),
        "commits": daemon.commits,
        "failures": len(daemon.failures),
        "probe_readmits": len(daemon.probe_events),
        "compact_duration_s": _hist(daemon.metrics,
                                    "maintenance.compact.duration_s"),
        "merge_duration_s": _hist(daemon.metrics,
                                  "maintenance.merge.duration_s"),
    }


def cluster_stats(cluster) -> dict:
    """The cluster rollup.  ``groups`` is keyed by group id and carries
    each batcher's engine stats plus its health state (``up`` /
    ``down`` / ``drained`` -- ES STARTED/UNASSIGNED/excluded)."""
    reg = cluster.metrics
    health = cluster.health.snapshot()
    down, drained = set(health["down"]), set(health["drained"])
    groups = {}
    for g, b in enumerate(cluster.batchers):
        state = ("drained" if g in drained
                 else "down" if g in down else "up")
        groups[g] = {"health": state, **engine_stats(b)}
    out = {
        "n_groups": cluster.n_groups,
        "groups": groups,
        "requests": {
            "submitted": reg.value("cluster.requests.submitted"),
            "completed": reg.value("cluster.requests.completed"),
            "failed": reg.value("cluster.requests.failed"),
            "group_completed": {
                g: reg.value("cluster.requests.group_completed", group=g)
                for g in range(cluster.n_groups)},
        },
        "routing": {
            "spills": reg.value("cluster.routing.spills"),
            "failover_resubmits": reg.value("cluster.failover.resubmits"),
        },
        "health": {
            **health,
            "down_transitions": reg.total("health.down_transitions"),
            "readmits": reg.total("health.readmits"),
            "mark_ups": reg.total("health.mark_ups"),
        },
    }
    slowlog = getattr(cluster, "slowlog", None)
    if slowlog is not None:
        out["slowlog"] = slowlog.stats()
    watch = getattr(cluster, "compile_watch", None)
    if watch is not None:
        out["compile"] = _compile_stats(watch)
    if cluster.maintenance is not None:
        out["maintenance"] = _maintenance_stats(cluster.maintenance)
    if cluster.store is not None:
        out["store"] = store_stats(cluster.store)
    return out


def cluster_health(cluster) -> dict:
    """ES ``GET _cluster/health``: one green/yellow/red verdict derived
    from the HealthMap, plus everything an operator triages with --
    queue depths, in-flight restores, pending maintenance plans, and
    the transition ledger the verdict must reconcile against.

    Status derivation (the ES shard-allocation analogy, per replica
    group): **green** = every group routable; **yellow** = some groups
    down but at least one copy still serving (reduced redundancy, full
    availability -- exactly ES yellow); **red** = no routable group.

    Reconciliation contract (pinned by tests + ``make smoke-health``):
    the ledger's ``down`` events equal the ``health.down_transitions``
    counter total one-for-one (likewise ``up``/``readmit``), and
    replaying the ledger lands on the reported down-set -- the verdict
    can never drift from the events that produced it."""
    reg = cluster.metrics
    h = cluster.health.snapshot()
    down = set(h["down"])
    up_groups = h["n_groups"] - len(down)
    status = ("green" if not down
              else "yellow" if up_groups else "red")
    queue_depths = {}
    for g, b in enumerate(cluster.batchers):
        with b._lock:
            queue_depths[g] = len(b._queue) + b._inflight
    maint = (cluster.maintenance.pending_plans()
             if cluster.maintenance is not None else [])
    return {
        "status": status,
        "n_groups": h["n_groups"],
        "up_groups": up_groups,
        "down": h["down"],
        "drained": h["drained"],
        "generation": h["generation"],
        "queue_depths": queue_depths,
        "pending_requests": sum(queue_depths.values()),
        "in_flight_restores": getattr(cluster, "restores_in_flight", 0),
        "restores_completed": reg.total("cluster.restores"),
        "pending_maintenance": maint,
        "transitions": list(cluster.health.transitions()),
        "counters": {
            "down_transitions": reg.total("health.down_transitions"),
            "readmits": reg.total("health.readmits"),
            "mark_ups": reg.total("health.mark_ups"),
        },
    }


def format_health_line(health: dict) -> str:
    """One ``_cat/health``-style line from a :func:`cluster_health`
    dict: status, routable groups, pending work, restore/maintenance
    activity, cluster-state generation."""
    parts = [f"health {health['status']} "
             f"groups={health['up_groups']}/{health['n_groups']}up"]
    if health["down"]:
        parts.append("down=" + ",".join(str(g) for g in health["down"]))
    if health["drained"]:
        parts.append("drained="
                     + ",".join(str(g) for g in health["drained"]))
    parts.append(f"pending={health['pending_requests']}")
    parts.append(f"restores={health['in_flight_restores']}")
    parts.append(f"maint={len(health['pending_maintenance'])}")
    parts.append(f"gen={health['generation']}")
    return " ".join(parts)


def node_stats(engine) -> dict:
    """ES ``GET _nodes/stats``: per-device residency for everything the
    engine serves.  Every backend device gets a node entry (platform,
    process, backend ``memory_stats()`` where exposed -- None on CPU);
    index bytes are attributed per device through each leaf's physical
    shards (:func:`repro.obs.device.device_bytes`), split per replica
    group for a cluster."""
    import jax

    from repro.obs.device import device_bytes

    nodes: dict = {}
    for dev in jax.devices():
        ms = None
        try:
            ms = dev.memory_stats()
        except Exception:
            pass
        nodes[str(dev)] = {
            "platform": dev.platform,
            "process_index": int(dev.process_index),
            "index_bytes": 0,
            "index_bytes_by_group": {},
            "memory_stats": ms,
        }
    batchers = getattr(engine, "batchers", None)
    if batchers is not None:
        indexes = [(g, b.index) for g, b in enumerate(batchers)]
    else:
        indexes = [(0, engine.index)]
    total = 0
    for g, idx in indexes:
        db = device_bytes(idx, reconcile=False)
        total += db["total_bytes"]
        for dstr, b in db["per_device"].items():
            node = nodes.setdefault(dstr, {
                "platform": "?", "process_index": 0, "index_bytes": 0,
                "index_bytes_by_group": {}, "memory_stats": None})
            node["index_bytes"] += b
            node["index_bytes_by_group"][g] = (
                node["index_bytes_by_group"].get(g, 0) + b)
    return {
        "n_devices": len(nodes),
        "total_index_bytes": total,
        "device_resident_bytes": sum(n["index_bytes"]
                                     for n in nodes.values()),
        "nodes": nodes,
    }


def store_stats(store) -> dict:
    """Translog + commit section (ES ``_stats/translog``).  Bytes are
    the on-disk sum over retained generation files -- what a trim
    reclaims."""
    from repro.store.snapshot import latest_commit

    reg = store.metrics
    tl = store.translog
    tl_bytes = 0
    n_gens = 0
    try:
        for fn in os.listdir(store.path):
            if fn.startswith("translog-") and fn.endswith(".log"):
                n_gens += 1
                tl_bytes += os.path.getsize(os.path.join(store.path, fn))
    except OSError:  # pragma: no cover - dir raced away
        pass
    commit = latest_commit(store.path, validate=False)
    return {
        "path": store.path,
        "durability": store.durability,
        "translog": {
            "seqno": tl.seqno,
            "generation": tl.generation,
            "n_generations": n_gens,
            "bytes": tl_bytes,
        },
        "commit": (None if commit is None
                   else {"generation": commit.generation,
                         "seq": commit.seq}),
        "commits": reg.value("store.commits"),
        "recoveries": reg.value("store.recoveries"),
        "commit_duration_s": _hist(reg, "store.commit.duration_s"),
        "recovery_duration_s": _hist(reg, "store.recovery.duration_s"),
        # the incremental-commit evidence: last commit's changed bytes vs
        # the bytes it references (shared blobs make written << total)
        "commit_bytes": {
            "written_total": reg.value("store.commit.bytes_written"),
            "last_written": reg.value("store.commit.last_bytes_written"),
            "last_total": reg.value("store.commit.last_bytes_total"),
        },
    }


def _ms(v: Optional[float]) -> str:
    if v is None or (isinstance(v, float) and math.isnan(v)):
        return "-"
    if math.isinf(v):
        return "inf"
    return f"{v * 1e3:.1f}ms"


def format_segments_line(stats: dict) -> str:
    """One ``_cat/segments``-style line from an :func:`index_stats` dict:
    base docs, then each sealed generation as ``rows-tombstones``, then
    the active buffer -- the operator's glanceable view of the segment
    story (``seg`` entries read ``rows(-dead)``)."""
    base = stats.get("n_docs", stats.get("n_ids", 0))
    parts = [f"segments base={base}"]
    for i, s in enumerate(stats.get("segments", ())):
        dead = f"-{s['tombstones']}" if s["tombstones"] else ""
        parts.append(f"seg{i}={s['rows']}{dead}")
    if stats.get("n_active"):
        dead = stats.get("active_tombstones", 0)
        parts.append(f"active={stats['n_active']}"
                     + (f"-{dead}" if dead else ""))
    if stats.get("n_reclaimed"):
        parts.append(f"reclaimed={stats['n_reclaimed']}")
    if stats.get("n_tombstones"):
        parts.append(f"tombstones={stats['n_tombstones']}")
    return " ".join(parts)


def _kernel_field(mix: dict) -> str:
    """``kernel=codes:5/fused:3`` -- the fused/composed dispatch mix,
    sorted by path name so the rendering is deterministic."""
    return "/".join(f"{k}:{v}" for k, v in sorted(mix.items())) or "-"


def format_stats_line(stats: dict) -> str:
    """One compact ``_cat``-style line from a cluster OR engine stats
    dict (the ``--stats-interval`` periodic printer)."""
    if "groups" in stats:                      # cluster rollup
        req = stats["requests"]
        waits = [g["queue_wait_s"] for g in stats["groups"].values()]
        disp = [g["dispatch_latency_s"] for g in stats["groups"].values()]
        pend = sum(g["pending"] for g in stats["groups"].values())
        up = sum(1 for g in stats["groups"].values()
                 if g["health"] == "up")
        p99s = [h["p99"] for h in disp if h["p99"] is not None]
        w50s = [h["p50"] for h in waits if h["p50"] is not None]
        mix: dict = {}
        for g in stats["groups"].values():
            for eng, v in g.get("kernel_path", {}).items():
                mix[eng] = mix.get(eng, 0) + v
        return (f"stats groups={up}/{stats['n_groups']}up "
                f"pending={pend} "
                f"done={req['completed']}/{req['submitted']} "
                f"failed={req['failed']} "
                f"spills={stats['routing']['spills']} "
                f"resubmits={stats['routing']['failover_resubmits']} "
                f"kernel={_kernel_field(mix)} "
                f"wait_p50={_ms(max(w50s) if w50s else None)} "
                f"dispatch_p99={_ms(max(p99s) if p99s else None)}")
    req = stats["requests"]                    # single engine
    occ = stats["batches"]["p50"]
    return (f"stats pending={stats['pending']} "
            f"done={req['completed']}/{req['submitted']} "
            f"failed={req['failed']} "
            f"occupancy_p50={'-' if occ is None else format(occ, '.2f')} "
            f"kernel={_kernel_field(stats.get('kernel_path', {}))} "
            f"wait_p50={_ms(stats['queue_wait_s']['p50'])} "
            f"dispatch_p99={_ms(stats['dispatch_latency_s']['p99'])}")
