"""ClusterEngine: per-replica-group request batchers + failover routing.

The coordinating-node control plane over the sharded data plane.  A
``(data, replica)`` mesh gives R bit-identical serving copies of the
doc-sharded corpus, but one :class:`~repro.serve.engine.BatchedSearchEngine`
fronting the whole mesh only materialises that parallelism *inside a
single batch* (queries round-robin across groups within one SPMD call).
:class:`ClusterEngine` instead views each replica column as an
independent 1-D index (:meth:`ShardedVectorIndex.replica_group`) and runs
R independent batchers, one per group -- R concurrent search programs on
disjoint device sets, so concurrent QPS actually scales with R.

Routing (the ES coordinating node's copy selection):

* **stream affinity** -- a request stream (session id, user, connection)
  pins to one group on first sight, like ES ``preference=<custom_string>``
  session stickiness: the stream's queries batch together and hit one
  group's caches.
* **least-loaded spill** -- when the pinned group's ``pending`` depth
  exceeds ``spill_factor * batch_size``, overflow routes to the
  least-loaded healthy group (adaptive replica selection).  The pin is
  not rewritten: the stream returns home once the spike drains.
* **failover** -- a search failure marks the group down in the
  :class:`~repro.cluster.health.HealthMap` and transparently resubmits
  the affected requests to surviving copies (ES retries a failed fetch on
  the next shard copy).  Results are bit-identical to the healthy
  cluster, because every group computes bit-identical results.  Only when
  no healthy copy remains does the caller see the failure.

``inject_failure(group)`` is the failure-injection hook: it poisons that
group's index behind its batcher (every search raises), which exercises
the full detect -> mark_down -> resubmit path end to end without touching
devices.  ``heal`` + ``mark_up`` bring the group back.

Control-plane writes (``add_documents`` / ``delete``) apply to EVERY
group, down or not -- a downed copy must stay consistent for ``mark_up``,
exactly like ES replica recovery replaying the translog.  Deterministic
ingest routing guarantees every copy assigns identical gids.

``auto_compact=<threshold>`` starts a
:class:`~repro.cluster.maintenance.MaintenanceDaemon` that watches every
group's tombstone ratio and compacts in the background (hot CAS swap, no
dropped queries).

**Durability** (``store=``, :class:`repro.store.durable.Store`): group 0
is the *primary* -- its index wraps in a write-through
:class:`~repro.store.durable.DurableIndex`, so every cluster
``add_documents``/``delete`` hits the translog (group 0, first in the
fan-out, applies and logs before any replica group applies and before
the cluster acks), the ES primary-owns-the-translog arrangement; replica
groups apply without re-logging because every copy computes the
identical state.  :meth:`restore_group` is then the
recovery story PR 4 lacked: a replica group whose memory is gone is
rebuilt from commit point + translog replay onto its own device column
and re-admitted -- instead of staying down forever or leeching a sibling
copy's RAM.  Control-plane writes and restores serialize on one lock so
a restore can never miss a racing ingest.  ``probe_s=<seconds>`` runs
the background canary prober (see
:meth:`~repro.cluster.maintenance.MaintenanceDaemon.probe_once`) so
healed groups re-admit without a manual ``mark_up``.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from concurrent.futures import CancelledError, Future
from typing import List, Optional

import numpy as np

from repro.core import TrimFilter
from repro.obs.compile_watch import active_watch
from repro.obs.metrics import default_registry
from repro.obs.profile import ProfileNode
from repro.obs.slowlog import start_request_trace
from repro.obs.tracing import NULL_TRACE
from repro.serve.engine import BatchedSearchEngine

from .health import HealthMap
from .maintenance import MaintenanceDaemon

__all__ = ["ClusterEngine"]


class _FailpointIndex:
    """Failure-injection wrapper around one group's index.

    Transparent for every read (attribute access proxies through) but
    ``search`` raises while ``fail`` is set -- the hook ClusterEngine's
    failover path is exercised with.  The fail state lives in a CELL
    shared by every descendant wrapper: mutators (ingest/delete/compact)
    re-wrap their result around the same cell, so the failpoint the
    router holds keeps controlling the group through any number of hot
    swaps (a poisoned group that ingests stays poisoned until ``heal``).
    """

    def __init__(self, inner, cell: Optional[dict] = None):
        self._cell = cell if cell is not None else {"fail": None}
        self.inner = inner

    @property
    def fail(self) -> Optional[Exception]:
        return self._cell["fail"]

    @fail.setter
    def fail(self, exc: Optional[Exception]) -> None:
        self._cell["fail"] = exc

    def search(self, *args, **kwargs):
        if self.fail is not None:
            raise self.fail
        return self.inner.search(*args, **kwargs)

    def add_documents(self, vectors):
        return _FailpointIndex(self.inner.add_documents(vectors), self._cell)

    def delete(self, ids):
        return _FailpointIndex(self.inner.delete(ids), self._cell)

    def compact(self):
        return _FailpointIndex(self.inner.compact(), self._cell)

    def merge_segments(self, start: int = 0, count=None):
        return _FailpointIndex(self.inner.merge_segments(start, count),
                               self._cell)

    def __getattr__(self, name):
        return getattr(self.inner, name)


class ClusterEngine:
    def __init__(
        self,
        index,                            # ShardedVectorIndex | list of them
        batch_size: int = 32,
        max_wait_s: float = 0.005,
        k: int = 10,
        page: int = 320,
        trim: Optional[TrimFilter] = TrimFilter(0.05),
        engine: str = "codes",
        merge: Optional[str] = None,
        max_postings: "Optional[int | str]" = None,
        spill_factor: float = 2.0,
        max_stream_pins: int = 4096,
        auto_compact: Optional[float] = None,
        compact_interval_s: float = 0.05,
        store=None,
        probe_s: Optional[float] = None,
        metrics=None,
        tracer=None,
        slowlog=None,
        compile_watch=None,
    ):
        """``index`` is a ShardedVectorIndex (its R replica groups become
        the cluster's groups) or an explicit list of group indexes (full
        serving copies -- how tests run a multi-group cluster on one
        device).  ``auto_compact`` is a tombstone-ratio threshold; set, it
        starts the background maintenance daemon.  ``store`` attaches a
        durability directory (group 0 becomes the write-through primary,
        a baseline commit is written if none exists, and
        :meth:`restore_group` re-admits downed groups from disk).
        ``probe_s`` runs the background canary prober at that interval so
        healed groups re-admit automatically.  ``metrics``/``tracer``
        inject the observability plane (:mod:`repro.obs`): the registry
        is shared with every per-group batcher (series labelled
        ``group=g``) and the health map; the tracer samples per-request
        span traces that follow a query through routing, queue wait,
        and dispatch, with spill / failover-resubmit events attached."""
        if isinstance(index, (list, tuple)):
            groups = list(index)
        else:
            groups = [index.replica_group(g)
                      for g in range(index.n_replicas)]
        if not groups:
            raise ValueError("need at least one replica group")
        self.metrics = metrics if metrics is not None else default_registry()
        self.tracer = tracer
        # request-level tail capture lives at the CLUSTER seam (one
        # skeleton per request, spanning routing + failover resubmits);
        # per-group batchers receive traces from here, never admit their
        # own (repro.obs.slowlog)
        self.slowlog = slowlog
        self.compile_watch = (compile_watch if compile_watch is not None
                              else active_watch())
        self.store = store
        if store is not None:
            from repro.store.durable import DurableIndex

            # an explicitly injected store registry wins; a store on the
            # process default joins the cluster's registry so one
            # stats() rollup sees everything -- joined BEFORE open_index,
            # whose baseline commit must land in the cluster's counters
            if store.metrics is default_registry():
                store.metrics = self.metrics
            if not isinstance(groups[0], DurableIndex):
                groups[0] = store.open_index(groups[0])
        self._failpoints = [_FailpointIndex(g) for g in groups]
        self.health = HealthMap(len(groups), metrics=self.metrics)
        self._batchers: List[BatchedSearchEngine] = [
            BatchedSearchEngine(
                fp, batch_size=batch_size, max_wait_s=max_wait_s, k=k,
                page=page, trim=trim, engine=engine, merge=merge,
                max_postings=max_postings, metrics=self.metrics, group=g,
                compile_watch=self.compile_watch)
            for g, fp in enumerate(self._failpoints)
        ]
        self._c_submitted = self.metrics.counter("cluster.requests.submitted")
        self._c_completed = self.metrics.counter("cluster.requests.completed")
        self._c_failed = self.metrics.counter("cluster.requests.failed")
        self._c_spills = self.metrics.counter("cluster.routing.spills")
        self._c_resubmits = self.metrics.counter("cluster.failover.resubmits")
        self._c_group_completed = [
            self.metrics.counter("cluster.requests.group_completed", group=g)
            for g in range(len(groups))]
        self.spill_threshold = max(1, int(spill_factor * batch_size))
        # LRU-capped pin map: stream ids are caller-supplied (sessions,
        # connections), so an uncapped map is an unbounded leak in a
        # long-lived service.  Evicting a cold pin is benign -- every
        # group returns bit-identical results, the stream just re-pins.
        self.max_stream_pins = max(1, max_stream_pins)
        self._streams: OrderedDict = OrderedDict()
        self._lock = threading.Lock()
        # serializes control-plane writes (ingest/delete) against
        # restore_group's recover-then-swap, so a restore can never miss
        # an op that landed between its disk read and its swap
        self._ctl_lock = threading.Lock()
        # restores in flight, for _cluster/health (guarded by _lock, not
        # _ctl_lock: health polls must not block behind a running restore)
        self._restores_inflight = 0
        self._closed = False
        self.maintenance: Optional[MaintenanceDaemon] = None
        if auto_compact is not None or probe_s is not None:
            # compaction sweeps and canary probes keep independent
            # cadences (the daemon thread ticks at the faster of the two)
            self.maintenance = MaintenanceDaemon(
                self._batchers,
                threshold=(auto_compact if auto_compact is not None
                           else float("inf")),
                interval_s=(compact_interval_s if auto_compact is not None
                            else probe_s),
                probe_interval_s=probe_s,
                health=self.health, store=store,
                probe=probe_s is not None,
                # probe-only daemons (auto_compact=None) must not start
                # background merges either -- maintenance work is opt-in
                merge_policy=("auto" if auto_compact is not None else None),
                metrics=self.metrics).start()

    # ------------------------------------------------------------ topology
    @property
    def n_groups(self) -> int:
        return len(self._batchers)

    @property
    def batchers(self):
        """The per-group batchers (read-only view; load/ingest state)."""
        return tuple(self._batchers)

    def group_index(self, group: int):
        """The index currently served by ``group`` (unwrapped)."""
        return self._batchers[group].index.inner

    def loads(self):
        """(pending per group) -- the router's own routing signal."""
        return tuple(b.pending for b in self._batchers)

    def stats(self) -> dict:
        """ES ``_cluster/stats`` + ``_cat/shards``-style rollup: per-group
        batcher stats + health state, routing counters (spills, failover
        resubmits, per-group completions -- their sum reconciles exactly
        with queries issued), health-transition counters, and the
        maintenance/store sections when wired (see
        :func:`repro.obs.stats.cluster_stats`)."""
        from repro.obs.stats import cluster_stats

        return cluster_stats(self)

    def cluster_health(self) -> dict:
        """ES ``GET _cluster/health``: green/yellow/red from the
        HealthMap plus queue depths, in-flight restores, pending
        maintenance plans, and the transition ledger (see
        :func:`repro.obs.stats.cluster_health`)."""
        from repro.obs.stats import cluster_health

        return cluster_health(self)

    def node_stats(self) -> dict:
        """ES ``GET _nodes/stats``: per-device index residency across
        every replica group (see :func:`repro.obs.stats.node_stats`)."""
        from repro.obs.stats import node_stats

        return node_stats(self)

    # ------------------------------------------------------------- routing
    def _pick(self, stream, exclude=(), trace=NULL_TRACE) -> int:
        up = [g for g in self.health.up_groups() if g not in exclude]
        if not up:
            raise RuntimeError("no healthy replica group available")
        least = min(up, key=lambda g: self._batchers[g].pending)
        if stream is None:
            return least
        with self._lock:
            pinned = self._streams.get(stream)
            if pinned is None:
                self._streams[stream] = pinned = least
            self._streams.move_to_end(stream)
            while len(self._streams) > self.max_stream_pins:
                self._streams.popitem(last=False)
        if pinned in up and self._batchers[pinned].pending <= self.spill_threshold:
            return pinned
        if pinned in up and least != pinned:
            # the pinned group is healthy but over the spill threshold:
            # this request overflows to the least-loaded copy (adaptive
            # replica selection) -- a routing event worth metering
            self._c_spills.inc()
            trace.event("spill", from_group=pinned, to_group=least)
        return least                      # spill; the pin itself persists

    def submit(self, query_vec: np.ndarray, stream=None) -> Future:
        """Route one query -> Future of (ids, scores).

        The returned future resolves even through a group failure: the
        completion callback marks the failed group down and resubmits to
        the next healthy copy (each copy tried at most once).  Only with
        no healthy copy left does the future carry the failure."""
        if self._closed:
            raise RuntimeError("engine closed")
        outer: Future = Future()
        q = np.asarray(query_vec, np.float32)
        tried: set = set()
        marked: list = []                 # groups THIS request marked down
        trace = start_request_trace(self.tracer, self.slowlog, "query",
                                    stream=stream)
        self._c_submitted.inc()

        def attempt(prev_exc=None):
            try:
                g = self._pick(stream, exclude=tried, trace=trace)
            except RuntimeError as exc:
                if prev_exc is not None:
                    # every copy failed the SAME request: the request, not
                    # the cluster, is the likely fault (a genuinely dead
                    # copy fails while its siblings answer) -- undo this
                    # request's mark_downs so one poisoned query cannot
                    # black-hole the whole cluster, and surface the error.
                    # readmit, not mark_up: an operator drain recorded
                    # while this request was in flight must survive
                    for m in marked:
                        self.health.readmit(m)
                        trace.event("rollback_readmit", group=m)
                self._c_failed.inc()
                err = prev_exc or exc
                trace.finish(error=repr(err))
                if not outer.done():
                    outer.set_exception(err)
                return
            tried.add(g)
            try:
                inner = self._batchers[g].submit(q, trace=trace)
            except RuntimeError as exc:   # batcher closed under us
                self._c_failed.inc()
                err = prev_exc or exc
                trace.finish(error=repr(err))
                if not outer.done():
                    outer.set_exception(err)
                return
            if prev_exc is not None:      # this attempt IS the resubmit
                self._c_resubmits.inc()
                trace.event("failover_resubmit", group=g,
                            error=repr(prev_exc))
            inner.add_done_callback(lambda f: _finish(f, g))

        def _finish(inner: Future, g: int):
            if outer.cancelled():
                trace.finish(error="cancelled")
                return
            try:
                exc = inner.exception()
            except CancelledError as cancel:
                exc = cancel
            if exc is None:
                self._c_completed.inc()
                self._c_group_completed[g].inc()
                trace.finish()
                if not outer.done():
                    outer.set_result(inner.result())
                return
            # failover: this copy is bad -- take it out of routing and
            # replay the request on the next healthy copy
            if self.health.mark_down(g):
                marked.append(g)
                trace.event("group_down", group=g)
            attempt(prev_exc=exc)

        attempt()
        return outer

    def search(self, query_vec: np.ndarray, stream=None,
               timeout: float = 10.0):
        return self.submit(query_vec, stream=stream).result(timeout=timeout)

    def profile(self, query_vec: np.ndarray, stream=None,
                timeout: float = 10.0):
        """ES ``_search?profile=true``: one query -> ``(ids, scores,
        profile_dict)`` where the tree adds the cluster's routing phase
        (group picked, healthy-copy count) on top of the chosen group's
        engine profile (queue wait -> batch form -> dispatch -> the
        index's phase children).  Scores are bit-identical to
        :meth:`search` -- profiling only fences phase boundaries.

        The profile path routes once and does NOT fail over (a profile
        of a failed dispatch would profile the wrong thing); the error
        propagates so the caller can fall back to :meth:`search`.
        """
        if self._closed:
            raise RuntimeError("engine closed")
        q = np.asarray(query_vec, np.float32)
        t0 = time.monotonic()
        root = ProfileNode("cluster.query", n_groups=self.n_groups,
                           **({} if stream is None else {"stream": stream}))
        up = len(self.health.up_groups())
        g = self._pick(stream)
        t_route = time.monotonic()
        self._c_submitted.inc()
        root.child("route", t_route - t0, group=g, up_groups=up)
        try:
            ids, scores, prof = self._batchers[g].submit(
                q, profile=True).result(timeout=timeout)
        except Exception:
            self._c_failed.inc()
            raise
        self._c_completed.inc()
        self._c_group_completed[g].inc()
        root.children.append(prof)
        root.duration_s = time.monotonic() - t0
        return ids, scores, root.to_dict()

    # ------------------------------------------------------- control plane
    def add_documents(self, vectors) -> int:
        """Hot-add documents to EVERY replica group (down groups included:
        a copy must stay consistent to be markable up again).  Returns the
        first assigned global id -- identical in every group because
        ingest routing is deterministic.  With a store attached, group 0
        (first in the fan-out) write-throughs the translog, so the op is
        durable before any group acks."""
        with self._ctl_lock:
            firsts = {b.add_documents(vectors) for b in self._batchers}
        if len(firsts) != 1:              # pragma: no cover - invariant
            raise RuntimeError(f"replica groups diverged: first ids {firsts}")
        return firsts.pop()

    def delete(self, ids) -> None:
        """Hot-tombstone documents in every replica group."""
        with self._ctl_lock:
            for b in self._batchers:
                b.delete(ids)

    def restore_group(self, group: int, mesh=None) -> int:
        """Re-admit replica group ``group`` from DISK: crash-recover the
        index (latest commit point + translog replay) onto the group's
        own device column, swap it behind the group's batcher, clear any
        injected fault, and mark the group up.  Returns the recovered
        translog seqno.

        This is the path PR 4 could not express: a group whose in-memory
        copy is lost (not merely unrouted) comes back from durable state
        instead of staying down.  Runs under the control-plane write lock,
        so every op acked before the restore is in the recovered state and
        every op after it applies to the swapped index -- the restored
        copy is bit-identical to its surviving siblings (pinned by
        tests/test_store.py on the 4x2 mesh)."""
        if self.store is None:
            raise RuntimeError(
                "no store attached; construct ClusterEngine(store=...)")
        if not 0 <= group < self.n_groups:
            raise ValueError(
                f"group must be in [0, {self.n_groups}), got {group}")
        from repro.store.durable import DurableIndex

        with self._lock:
            self._restores_inflight += 1
        try:
            with self._ctl_lock:
                if mesh is None:
                    mesh = self._batchers[group].index.mesh
                index, seq = self.store.recover_index(mesh)
                if group == 0:            # the primary keeps write-through
                    index = DurableIndex(index, self.store, seq=seq)
                fp = _FailpointIndex(index, self._failpoints[group]._cell)
                fp.fail = None            # restoring clears the fault
                self._failpoints[group] = fp
                self._batchers[group].swap_index(fp)
        finally:
            with self._lock:
                self._restores_inflight -= 1
        self.health.mark_up(group)
        self.metrics.counter("cluster.restores", group=group).inc()
        return seq

    @property
    def restores_in_flight(self) -> int:
        """Disk restores currently running (ES recoveries in flight --
        a ``_cluster/health`` field)."""
        with self._lock:
            return self._restores_inflight

    # ------------------------------------------------------------- health
    def mark_down(self, group: int) -> bool:
        """Operator/drain hook: stop routing NEW work to ``group``.
        Requests already queued on its batcher drain normally.  Recorded
        as a DRAIN (operator intent), so the background canary prober
        will not re-admit the group behind the operator's back -- only
        :meth:`mark_up` (or :meth:`restore_group`) brings it back.  The
        failover path marks downs through ``health.mark_down`` directly
        (a fault, probe-eligible)."""
        return self.health.mark_down(group, drain=True)

    def mark_up(self, group: int) -> bool:
        return self.health.mark_up(group)

    def inject_failure(self, group: int, exc: Optional[Exception] = None):
        """Failure injection: every search on ``group`` raises until
        :meth:`heal`.  The routing layer discovers it the honest way -- a
        failed request -- and fails over."""
        self._failpoints[group].fail = exc if exc is not None else (
            RuntimeError(f"injected failure: replica group {group} is down"))

    def heal(self, group: int) -> None:
        """Clear an injected failure (does not flip health: pair with
        :meth:`mark_up`, the way an ES node rejoin is a separate event
        from the fault clearing)."""
        self._failpoints[group].fail = None

    # ----------------------------------------------------------- lifecycle
    def close(self):
        self._closed = True
        if self.maintenance is not None:
            self.maintenance.stop()
        for b in self._batchers:
            b.close()
