"""Replica-group health map (the ES cluster-state routing table).

:class:`HealthMap` tracks which replica groups are routable.  It is the
cluster's single source of routing truth, the analogue of Elasticsearch's
cluster state marking shard copies ``STARTED`` vs ``UNASSIGNED``: the
router consults it on every pick, failover marks a group down when a
search against it fails, and an operator (or test) flips groups with
``mark_down``/``mark_up`` the way ES applies shard-failed cluster-state
updates.

Marking a group down is a ROUTING decision only -- requests already queued
on the group's batcher drain normally (the index may be perfectly healthy,
e.g. a rolling restart); only new picks avoid it.  Actually-dead groups
are handled one level up: the router's failure path marks the group down
*and* resubmits the failed requests to a surviving copy.

Two kinds of down (the ES allocation-``exclude`` vs shard-failed
distinction): ``mark_down(g)`` records a FAULT -- the canary prober
(:meth:`~repro.cluster.maintenance.MaintenanceDaemon.probe_once`) may
re-admit the group once it answers again; ``mark_down(g, drain=True)``
records OPERATOR INTENT -- the group is deliberately out of routing
(rolling restart, debugging) and stays down, however healthy its
canaries look, until an explicit ``mark_up``.  ``mark_up`` clears both.

Thread-safe; every mutation bumps ``generation`` (ES cluster-state
version) so pollers can cheaply detect change.

Health *transitions* are the cluster's availability ledger, so they are
metered (:mod:`repro.obs.metrics`): ``health.down_transitions`` /
``health.mark_ups`` / ``health.readmits`` count per-group state CHANGES
(a re-mark of an already-down group counts nothing), which is what lets
the stats layer assert "one injected failure == one down/readmit pair".
On top of the counters, a bounded in-memory ledger
(:meth:`HealthMap.transitions`) records each transition with the
generation it produced, so ``cluster_health()`` can reconcile its
green/yellow/red verdict EXACTLY against the event history: the number
of ``down`` ledger events must equal the ``health.down_transitions``
counter total, and replaying the ledger must land on the current
down-set (the PR 6 schema-contract style, applied to availability).
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Tuple

# transitions kept for reconciliation; ES keeps a similarly bounded
# cluster-state update log.  Old entries fall off but the counters keep
# exact lifetime totals.
_LEDGER_CAPACITY = 1024

from repro.obs.metrics import default_registry

__all__ = ["HealthMap"]


class HealthMap:
    def __init__(self, n_groups: int, metrics=None):
        if n_groups < 1:
            raise ValueError(f"need at least one replica group, got {n_groups}")
        self.n_groups = n_groups
        self.metrics = metrics if metrics is not None else default_registry()
        self._down: set = set()
        self._drained: set = set()
        self._lock = threading.Lock()
        self._generation = 0
        self._events: deque = deque(maxlen=_LEDGER_CAPACITY)

    def _log(self, event: str, group: int) -> None:
        """Append one transition to the ledger.  Caller holds ``_lock``
        and has already bumped ``generation`` -- the recorded generation
        is the one this transition produced."""
        self._events.append({"event": event, "group": group,
                             "generation": self._generation})

    def _check(self, group: int) -> None:
        if not 0 <= group < self.n_groups:
            raise ValueError(
                f"group must be in [0, {self.n_groups}), got {group}")

    def mark_down(self, group: int, drain: bool = False) -> bool:
        """Stop routing to ``group``; returns True if anything changed
        (down flipped OR a new drain intent was recorded -- both bump
        ``generation``).  ``drain=True`` records operator intent: the
        group is exempt from canary re-admission until an explicit
        :meth:`mark_up` (draining an already-down group still records
        the intent)."""
        self._check(group)
        with self._lock:
            changed = False
            went_down = False
            drained = False
            if drain and group not in self._drained:
                self._drained.add(group)
                changed = drained = True
            if group not in self._down:
                self._down.add(group)
                changed = went_down = True
            if changed:
                self._generation += 1
            if went_down:
                self._log("down", group)
            if drained:
                self._log("drain", group)
        if went_down:
            self.metrics.counter("health.down_transitions", group=group).inc()
        return changed

    def mark_up(self, group: int) -> bool:
        """Restore routing to ``group``, clearing any drain intent (this
        is the operator's explicit rejoin); returns True if the ROUTING
        state changed (a drain-only clear still bumps ``generation``)."""
        self._check(group)
        with self._lock:
            was_drained = group in self._drained
            came_up = group in self._down
            if was_drained or came_up:
                self._generation += 1
            self._drained.discard(group)
            self._down.discard(group)
            if came_up:
                self._log("up", group)
            elif was_drained:
                self._log("undrain", group)
        if came_up:
            self.metrics.counter("health.mark_ups", group=group).inc()
        return came_up

    def readmit(self, group: int) -> bool:
        """``mark_up`` UNLESS an operator drain is in force -- atomic, so
        a drain recorded while a canary was in flight can never be undone
        by its success (the prober's and the failover rollback's entry
        point; only the operator's :meth:`mark_up` clears a drain)."""
        self._check(group)
        with self._lock:
            if group in self._drained or group not in self._down:
                return False
            self._down.discard(group)
            self._generation += 1
            self._log("readmit", group)
        self.metrics.counter("health.readmits", group=group).inc()
        return True

    def transitions(self) -> Tuple[dict, ...]:
        """The transition ledger, oldest first: ``{"event": "down" |
        "drain" | "up" | "undrain" | "readmit", "group": g,
        "generation": gen}`` per state change.  ``down`` entries match
        the ``health.down_transitions`` counter one-for-one (likewise
        ``up``/``mark_ups`` and ``readmit``/``readmits``) until the
        bounded ledger wraps -- the exact-reconciliation seam
        ``cluster_health()`` checks."""
        with self._lock:
            return tuple(dict(e) for e in self._events)

    def is_drained(self, group: int) -> bool:
        """True while an operator drain (``mark_down(g, drain=True)``)
        is in force -- the prober must not re-admit such a group."""
        self._check(group)
        with self._lock:
            return group in self._drained

    def is_up(self, group: int) -> bool:
        self._check(group)
        with self._lock:
            return group not in self._down

    def up_groups(self) -> Tuple[int, ...]:
        """Routable groups, ascending (possibly empty: a full outage)."""
        with self._lock:
            return tuple(g for g in range(self.n_groups)
                         if g not in self._down)

    @property
    def generation(self) -> int:
        with self._lock:
            return self._generation

    def snapshot(self) -> dict:
        with self._lock:
            return {"n_groups": self.n_groups,
                    "down": tuple(sorted(self._down)),
                    "drained": tuple(sorted(self._drained)),
                    "generation": self._generation}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        s = self.snapshot()
        return (f"HealthMap({s['n_groups']} groups, down={s['down']}, "
                f"gen={s['generation']})")
