"""Background auto-compaction daemon (the Lucene merge scheduler).

Elasticsearch never asks the operator to reclaim deleted docs: a
background merge policy watches each shard's deletes ratio
(``index.merge.policy.deletes_pct_allowed``) and rewrites segments when it
drifts too high.  :class:`MaintenanceDaemon` is that loop for the serving
tier: it polls every engine's ``index.tombstone_ratio`` (worst per-shard
dead fraction, maintained host-side by ``ShardedVectorIndex.delete``) and
past ``threshold`` (default 20%) runs ``compact()`` -- the on-device
sharded rebuild over the live doc table -- then hot-swaps the result in
via :meth:`BatchedSearchEngine.swap_index`.

The swap discipline is what makes this safe under live traffic:

* the expensive rebuild runs OUTSIDE the engine lock, against a snapshot
  of the served index;
* the swap is a compare-and-swap on that snapshot -- if an ingest or
  delete landed meanwhile (``self.index`` moved), the stale rebuild is
  simply dropped and the next tick retries against fresh state;
* in-flight batches finish on the index they dequeued with; no query is
  ever dropped or served a half-built index.

Compaction preserves global ids and exact df (the delete path already
keeps df exact), so results are unchanged across a background compact
apart from tombstone-free posting lists.

Down groups (per the cluster :class:`~repro.cluster.health.HealthMap`)
are skipped -- a dead copy is failover's problem, not maintenance's.  A
rebuild that ITSELF fails (device OOM, compile error) is recorded in
``failures`` and its snapshot quarantined, so the daemon neither dies nor
hot-loops the same expensive failure; the next ingest/delete produces a
new snapshot and re-arms the group.

**Durability** (``store=``, :class:`repro.store.durable.Store`): after a
successful compact-and-swap of an index that carries ``translog_seq``
(the :class:`~repro.store.durable.DurableIndex` commit metadata riding
through the CAS), the daemon rolls a new commit point and trims the
replayed translog -- the ES flush that follows a merge.  The committed
(state, seq) pair is exactly the pair that won the CAS, so a racing
ingest can never be committed out from under its translog record.  A
failing commit (disk error) is recorded in ``failures``, never fatal.

**Health probing** (``probe=True``, needs ``health``): each background
tick also sends a canary query through every FAULTED group's batcher and
``mark_up``s the ones that answer -- the ES master re-promoting a shard
copy once it responds again, so re-admission after :meth:`ClusterEngine.
heal` (or a transient fault clearing) no longer requires a manual
``mark_up`` or a poisoned-request rollback.  Operator-DRAINED groups
(``mark_down(g, drain=True)``, the ClusterEngine operator hook) are
exempt: a drain is intent, not a fault, and the prober must not undo it
behind the operator's back.  A canary that fails leaves the group down
and is not recorded as a failure (down is its steady state).
``probe_once()`` is the deterministic entry point.

``poll_once()`` exposes one deterministic compaction sweep for tests;
``start()`` runs poll + probe on a daemon thread every ``interval_s``.
"""

from __future__ import annotations

import threading
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.obs.metrics import default_registry

__all__ = ["MaintenanceDaemon"]


class MaintenanceDaemon:
    def __init__(
        self,
        batchers: Sequence,               # BatchedSearchEngine per group
        threshold: float = 0.2,
        interval_s: float = 0.05,
        health=None,                      # Optional[HealthMap]
        store=None,                       # Optional[repro.store.Store]
        probe: bool = False,
        probe_timeout_s: float = 5.0,
        probe_interval_s: Optional[float] = None,
        metrics=None,
    ):
        if not 0.0 < threshold:
            raise ValueError(f"threshold must be positive, got {threshold}")
        if probe and health is None:
            raise ValueError("probe=True needs a HealthMap to mark_up into")
        self._batchers = list(batchers)
        # compaction/commit wall times feed the stats layer (the ES merge
        # stats); timestamps are host-side around the rebuild dispatch
        self.metrics = metrics if metrics is not None else default_registry()
        self.threshold = threshold
        self.interval_s = interval_s
        self._health = health
        self._store = store
        self.probe = probe
        self.probe_timeout_s = probe_timeout_s
        # probing runs on its own cadence (default: every compaction tick);
        # the two loops share the thread but not the clock, so a fast
        # compaction interval does not turn into a canary storm and vice
        # versa
        self.probe_interval_s = (interval_s if probe_interval_s is None
                                 else probe_interval_s)
        self._probes: dict = {}           # group -> in-flight canary Future
        self._stop_evt = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.events: List[dict] = []      # one entry per applied compaction
        self.failures: List[dict] = []    # one entry per failed rebuild
        self.probe_events: List[dict] = []  # one entry per re-admission
        self.commits: int = 0             # commit points rolled post-compact
        self._quarantine: dict = {}       # group -> snapshot whose rebuild
        #                                   failed; skipped until it changes

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "MaintenanceDaemon":
        if self._thread is None:
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    @property
    def compactions(self) -> int:
        return len(self.events)

    # ----------------------------------------------------------------- work
    def poll_once(self) -> int:
        """One maintenance sweep over every group; returns compactions
        applied.  Deterministic entry point for tests and operators."""
        applied = 0
        for g, batcher in enumerate(self._batchers):
            if self._health is not None and not self._health.is_up(g):
                continue
            snapshot = batcher.index
            ratio = getattr(snapshot, "tombstone_ratio", 0.0)
            if ratio <= self.threshold:
                continue
            if self._quarantine.get(g) is snapshot:
                continue    # this exact state already failed to rebuild --
                #             don't hot-loop the failure; any ingest/delete
                #             produces a new snapshot and re-arms the group
            t0 = time.monotonic()
            try:
                compacted = snapshot.compact()        # outside the lock
            except Exception as exc:  # noqa: BLE001 - recorded, not fatal
                # a failing on-device rebuild (OOM, compile error) must not
                # kill maintenance for the healthy groups -- log it and
                # quarantine the snapshot instead of silently retrying the
                # same expensive failure every tick
                self._quarantine[g] = snapshot
                self.failures.append({"group": g, "tombstone_ratio": ratio,
                                      "error": repr(exc)})
                self.metrics.counter("maintenance.failures", group=g).inc()
                continue
            duration = time.monotonic() - t0
            try:
                swapped = batcher.swap_index(compacted, expected=snapshot)
            except RuntimeError:
                continue                              # engine closed mid-sweep
            if swapped:
                self._quarantine.pop(g, None)
                applied += 1
                self.events.append({
                    "group": g,
                    "tombstone_ratio": ratio,
                    "n_ids": snapshot.n_ids,
                    "duration_s": duration,
                })
                self.metrics.counter("maintenance.compactions",
                                     group=g).inc()
                self.metrics.histogram(
                    "maintenance.compact.duration_s").observe(duration)
                self._commit(g, compacted)
            # CAS miss: an ingest/delete raced the rebuild -- the next
            # sweep re-evaluates the fresh index
        return applied

    def _commit(self, g: int, compacted) -> None:
        """Roll a commit point for the state that won the CAS (the ES
        flush after a merge).  ``compacted`` is OUR reference to the
        swapped-in index, so its (state, translog_seq) pair stays
        consistent even if a racing ingest has already moved the engine
        past it -- the racer's ops sit after ``translog_seq`` in the log
        and replay on top of this commit."""
        seq = getattr(compacted, "translog_seq", None)
        if self._store is None or seq is None:
            return
        try:
            self._store.commit(compacted, seq)
            self.commits += 1
        except Exception as exc:  # noqa: BLE001 - disk faults not fatal
            self.failures.append({"group": g, "commit_seq": seq,
                                  "error": repr(exc)})

    def probe_once(self) -> int:
        """Canary-probe every FAULTED group; readmit the ones that
        answer.  Returns groups re-admitted.  The canary goes through the
        group's real batcher (the honest path -- a group is healthy when
        it can serve, not when a side channel says so); routing never
        sees it because routing already avoids down groups.

        Canaries are tracked as in-flight futures: a FRESH canary gets a
        bounded ``probe_timeout_s`` window (so the deterministic
        ``probe_once()`` re-admits a responsive group in one call), but a
        canary that is still pending after that is left in flight and
        merely polled on later ticks -- a HUNG group costs its window
        once, not per tick, and can never starve the compaction sweeps
        sharing this thread.  Re-admission goes through
        ``HealthMap.readmit`` (atomic mark-up-unless-drained), so an
        operator drain recorded while the canary was in flight survives
        its success."""
        if self._health is None:
            return 0
        is_drained = getattr(self._health, "is_drained", lambda g: False)
        readmit = getattr(self._health, "readmit", self._health.mark_up)
        readmitted = 0
        for g, batcher in enumerate(self._batchers):
            if self._health.is_up(g) or is_drained(g):
                self._probes.pop(g, None)   # stale canary: nobody to admit
                continue
            fut = self._probes.get(g)
            if fut is None:
                try:
                    canary = np.ones((batcher.index.n_features,),
                                     np.float32)
                    fut = batcher.submit(canary)
                except Exception:  # noqa: BLE001 - closed/broken batcher
                    continue
                self._probes[g] = fut
                try:
                    fut.result(timeout=self.probe_timeout_s)
                except Exception:  # noqa: BLE001 - timeout OR canary error
                    pass
            if not fut.done():
                continue                    # hung: poll again next tick
            self._probes.pop(g, None)
            try:
                if fut.exception() is not None:
                    continue                # still faulty: steady state
            except BaseException:           # noqa: BLE001 - cancelled
                continue
            if readmit(g):
                readmitted += 1
                self.probe_events.append({"group": g})
                self.metrics.counter("maintenance.probe.readmits",
                                     group=g).inc()
        return readmitted

    def _run(self) -> None:
        tick = self.interval_s
        if self.probe:
            tick = min(tick, self.probe_interval_s)
        poll_at = probe_at = 0.0
        while not self._stop_evt.wait(tick):
            now = time.monotonic()
            if now >= poll_at:
                self.poll_once()
                poll_at = time.monotonic() + self.interval_s
            if self.probe and now >= probe_at:
                self.probe_once()
                probe_at = time.monotonic() + self.probe_interval_s
