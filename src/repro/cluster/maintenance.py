"""Background auto-compaction daemon (the Lucene merge scheduler).

Elasticsearch never asks the operator to reclaim deleted docs: a
background merge policy watches each shard's deletes ratio
(``index.merge.policy.deletes_pct_allowed``) and rewrites segments when it
drifts too high.  :class:`MaintenanceDaemon` is that loop for the serving
tier: it polls every engine's ``index.tombstone_ratio`` (worst per-shard
dead fraction, maintained host-side by ``ShardedVectorIndex.delete``) and
past ``threshold`` (default 20%) runs ``compact()`` -- the on-device
sharded rebuild over the live doc table -- then hot-swaps the result in
via :meth:`BatchedSearchEngine.swap_index`.

The swap discipline is what makes this safe under live traffic:

* the expensive rebuild runs OUTSIDE the engine lock, against a snapshot
  of the served index;
* the swap is a compare-and-swap on that snapshot -- if an ingest or
  delete landed meanwhile (``self.index`` moved), the stale rebuild is
  simply dropped and the next tick retries against fresh state;
* in-flight batches finish on the index they dequeued with; no query is
  ever dropped or served a half-built index.

Compaction preserves global ids and exact df (the delete path already
keeps df exact), so results are unchanged across a background compact
apart from tombstone-free posting lists.

Down groups (per the cluster :class:`~repro.cluster.health.HealthMap`)
are skipped -- a dead copy is failover's problem, not maintenance's.  A
rebuild that ITSELF fails (device OOM, compile error) is recorded in
``failures`` and its snapshot quarantined, so the daemon neither dies nor
hot-loops the same expensive failure; the next ingest/delete produces a
new snapshot and re-arms the group.

``poll_once()`` exposes one deterministic sweep for tests; ``start()``
runs it on a daemon thread every ``interval_s``.
"""

from __future__ import annotations

import threading
from typing import List, Optional, Sequence

__all__ = ["MaintenanceDaemon"]


class MaintenanceDaemon:
    def __init__(
        self,
        batchers: Sequence,               # BatchedSearchEngine per group
        threshold: float = 0.2,
        interval_s: float = 0.05,
        health=None,                      # Optional[HealthMap]
    ):
        if not 0.0 < threshold:
            raise ValueError(f"threshold must be positive, got {threshold}")
        self._batchers = list(batchers)
        self.threshold = threshold
        self.interval_s = interval_s
        self._health = health
        self._stop_evt = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.events: List[dict] = []      # one entry per applied compaction
        self.failures: List[dict] = []    # one entry per failed rebuild
        self._quarantine: dict = {}       # group -> snapshot whose rebuild
        #                                   failed; skipped until it changes

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "MaintenanceDaemon":
        if self._thread is None:
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    @property
    def compactions(self) -> int:
        return len(self.events)

    # ----------------------------------------------------------------- work
    def poll_once(self) -> int:
        """One maintenance sweep over every group; returns compactions
        applied.  Deterministic entry point for tests and operators."""
        applied = 0
        for g, batcher in enumerate(self._batchers):
            if self._health is not None and not self._health.is_up(g):
                continue
            snapshot = batcher.index
            ratio = getattr(snapshot, "tombstone_ratio", 0.0)
            if ratio <= self.threshold:
                continue
            if self._quarantine.get(g) is snapshot:
                continue    # this exact state already failed to rebuild --
                #             don't hot-loop the failure; any ingest/delete
                #             produces a new snapshot and re-arms the group
            try:
                compacted = snapshot.compact()        # outside the lock
            except Exception as exc:  # noqa: BLE001 - recorded, not fatal
                # a failing on-device rebuild (OOM, compile error) must not
                # kill maintenance for the healthy groups -- log it and
                # quarantine the snapshot instead of silently retrying the
                # same expensive failure every tick
                self._quarantine[g] = snapshot
                self.failures.append({"group": g, "tombstone_ratio": ratio,
                                      "error": repr(exc)})
                continue
            try:
                swapped = batcher.swap_index(compacted, expected=snapshot)
            except RuntimeError:
                continue                              # engine closed mid-sweep
            if swapped:
                self._quarantine.pop(g, None)
                applied += 1
                self.events.append({
                    "group": g,
                    "tombstone_ratio": ratio,
                    "n_ids": snapshot.n_ids,
                })
            # CAS miss: an ingest/delete raced the rebuild -- the next
            # sweep re-evaluates the fresh index
        return applied

    def _run(self) -> None:
        while not self._stop_evt.wait(self.interval_s):
            self.poll_once()
