"""Background tiered-merge + auto-compaction daemon (the Lucene merge
scheduler).

Elasticsearch never asks the operator to reclaim deleted docs or fold
segments: a background merge policy (Lucene ``TieredMergePolicy``) picks a
few similar-sized segments per pass, merges them off the query path, and
keeps the per-index segment count bounded while deletes are reclaimed
incrementally.  :class:`MaintenanceDaemon` is that loop for the serving
tier, and :class:`TieredMergePolicy` is its planner:

1. **Delete-pressure rewrite** -- any sealed segment whose per-segment
   ``deleted_ratio`` exceeds ``segment_deletes`` (ES
   ``deletes_pct_allowed``) is rewritten alone, reclaiming its tombstones
   without touching its neighbours.  This is what fixes the whole-index
   vs per-shard accounting drift: the daemon used to threshold only on
   the global ``tombstone_ratio``, which cannot see *which generation*
   the deletes hit.
2. **Tiered fold** -- a contiguous run of ``merge_factor`` similar-sized
   segments (max <= merge_factor * min rows, Lucene's tier criterion)
   merges into one, so N ingest-sealed generations fold into
   O(log_mf N) tiers instead of accumulating.
3. **Full compact, demoted** -- only when neither applies and the global
   ``tombstone_ratio`` (worst per-shard dead fraction -- now dominated by
   BASE deletes, since segment deletes are reclaimed by 1) still exceeds
   ``threshold`` does the old all-or-nothing ``compact()`` run: the final
   fold of the last tier.

Merge passes run CONCURRENTLY across replica groups (they are
independent copies; each pass touches only its own group's device column
and its own CAS), on short-lived worker threads only when more than one
group has work -- an idle tick spawns nothing.  Every applied pass
hot-swaps via :meth:`BatchedSearchEngine.swap_index`.

The swap discipline is what makes this safe under live traffic:

* the expensive rebuild runs OUTSIDE the engine lock, against a snapshot
  of the served index;
* the swap is a compare-and-swap on that snapshot -- if an ingest or
  delete landed meanwhile (``self.index`` moved), the stale rebuild is
  simply dropped and the next tick retries against fresh state;
* in-flight batches finish on the index they dequeued with; no query is
  ever dropped or served a half-built index.

Compaction preserves global ids and exact df (the delete path already
keeps df exact), so results are unchanged across a background compact
apart from tombstone-free posting lists.

Down groups (per the cluster :class:`~repro.cluster.health.HealthMap`)
are skipped -- a dead copy is failover's problem, not maintenance's.  A
rebuild that ITSELF fails (device OOM, compile error) is recorded in
``failures`` and its snapshot quarantined, so the daemon neither dies nor
hot-loops the same expensive failure; the next ingest/delete produces a
new snapshot and re-arms the group.

**Durability** (``store=``, :class:`repro.store.durable.Store`): after a
successful compact-and-swap of an index that carries ``translog_seq``
(the :class:`~repro.store.durable.DurableIndex` commit metadata riding
through the CAS), the daemon rolls a new commit point and trims the
replayed translog -- the ES flush that follows a merge.  The committed
(state, seq) pair is exactly the pair that won the CAS, so a racing
ingest can never be committed out from under its translog record.  A
failing commit (disk error) is recorded in ``failures``, never fatal.

**Health probing** (``probe=True``, needs ``health``): each background
tick also sends a canary query through every FAULTED group's batcher and
``mark_up``s the ones that answer -- the ES master re-promoting a shard
copy once it responds again, so re-admission after :meth:`ClusterEngine.
heal` (or a transient fault clearing) no longer requires a manual
``mark_up`` or a poisoned-request rollback.  Operator-DRAINED groups
(``mark_down(g, drain=True)``, the ClusterEngine operator hook) are
exempt: a drain is intent, not a fault, and the prober must not undo it
behind the operator's back.  A canary that fails leaves the group down
and is not recorded as a failure (down is its steady state).
``probe_once()`` is the deterministic entry point.

``poll_once()`` exposes one deterministic compaction sweep for tests;
``start()`` runs poll + probe on a daemon thread every ``interval_s``.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import List, Optional, Sequence

import numpy as np

from repro.obs.compile_watch import watch_region
from repro.obs.metrics import default_registry

__all__ = ["MaintenanceDaemon", "TieredMergePolicy"]


class TieredMergePolicy:
    """Lucene-``TieredMergePolicy``-style merge planner.

    ``select(index)`` inspects the index's sealed :class:`Segment`
    generations and returns one merge plan (a dict with ``start``/
    ``count``/``reason``) or ``None``.  Selection order: a segment past
    the per-segment ``segment_deletes`` ratio is rewritten alone
    (``count=1`` -- Lucene's singleton merge that exists purely to reclaim
    deletes); otherwise the first contiguous run of ``merge_factor``
    similar-sized segments (largest <= merge_factor * smallest, by rows)
    folds into one.  Indexes without segments (flat, or plain
    ``VectorIndex``) always yield ``None`` -- the daemon then falls back
    to the global compact threshold.
    """

    def __init__(self, merge_factor: int = 4, segment_deletes: float = 0.2):
        if merge_factor < 2:
            raise ValueError(f"merge_factor must be >= 2, got {merge_factor}")
        if not 0.0 < segment_deletes:
            raise ValueError(
                f"segment_deletes must be positive, got {segment_deletes}")
        self.merge_factor = merge_factor
        self.segment_deletes = segment_deletes

    def select(self, index) -> Optional[dict]:
        segs = getattr(index, "segments", ())
        if not segs:
            return None
        for i, s in enumerate(segs):
            if s.deleted_ratio > self.segment_deletes:
                return {"start": i, "count": 1, "reason": "deletes",
                        "deleted_ratio": s.deleted_ratio}
        mf = self.merge_factor
        if len(segs) >= mf:
            for i in range(len(segs) - mf + 1):
                rows = [max(s.n_rows, 1) for s in segs[i:i + mf]]
                if max(rows) <= mf * min(rows):
                    return {"start": i, "count": mf, "reason": "tier"}
        return None


class MaintenanceDaemon:
    def __init__(
        self,
        batchers: Sequence,               # BatchedSearchEngine per group
        threshold: float = 0.2,
        interval_s: float = 0.05,
        health=None,                      # Optional[HealthMap]
        store=None,                       # Optional[repro.store.Store]
        probe: bool = False,
        probe_timeout_s: float = 5.0,
        probe_interval_s: Optional[float] = None,
        metrics=None,
        merge_policy="auto",              # "auto" | None | TieredMergePolicy
    ):
        if not 0.0 < threshold:
            raise ValueError(f"threshold must be positive, got {threshold}")
        if probe and health is None:
            raise ValueError("probe=True needs a HealthMap to mark_up into")
        self._batchers = list(batchers)
        # compaction/commit wall times feed the stats layer (the ES merge
        # stats); timestamps are host-side around the rebuild dispatch
        self.metrics = metrics if metrics is not None else default_registry()
        self.threshold = threshold
        self.interval_s = interval_s
        self._health = health
        self._store = store
        self.probe = probe
        self.probe_timeout_s = probe_timeout_s
        # probing runs on its own cadence (default: every compaction tick);
        # the two loops share the thread but not the clock, so a fast
        # compaction interval does not turn into a canary storm and vice
        # versa
        self.probe_interval_s = (interval_s if probe_interval_s is None
                                 else probe_interval_s)
        self._probes: dict = {}           # group -> in-flight canary Future
        self.merge_policy = (TieredMergePolicy() if merge_policy == "auto"
                             else merge_policy)
        self._stop_evt = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.events: List[dict] = []      # one entry per applied compaction
        self.merge_events: List[dict] = []  # one entry per applied merge
        self.failures: List[dict] = []    # one entry per failed rebuild
        self.probe_events: List[dict] = []  # one entry per re-admission
        self.commits: int = 0             # commit points rolled post-pass
        self._quarantine: dict = {}       # group -> snapshot whose rebuild
        #                                   failed; skipped until it changes

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "MaintenanceDaemon":
        if self._thread is None:
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    @property
    def compactions(self) -> int:
        return len(self.events)

    @property
    def merges(self) -> int:
        return len(self.merge_events)

    # ----------------------------------------------------------------- work
    def poll_once(self) -> int:
        """One maintenance sweep over every group; returns passes applied
        (merges + compactions).  Deterministic entry point for tests and
        operators.

        Plan/apply split: a cheap host-side planning pass first decides
        per group whether a merge (the policy's pick) or a full compact
        (global tombstone pressure, the demoted last resort) is due; only
        groups WITH work get an apply pass, and when several have work the
        passes run concurrently -- replica groups are independent copies,
        each apply touches only its own device column, its own CAS, and
        the thread-safe store/metrics."""
        plans = self._plan()
        if not plans:
            return 0
        if len(plans) == 1:
            return self._apply(*plans[0])
        with ThreadPoolExecutor(max_workers=len(plans)) as ex:
            return sum(ex.map(lambda p: self._apply(*p), plans))

    def _plan(self) -> List[tuple]:
        """The host-side planning pass: ``(group, batcher, snapshot,
        plan)`` per group with work due.  Pure inspection -- no rebuild,
        no lock, no state change -- so it doubles as the
        ``_cluster/health`` pending-maintenance probe."""
        plans = []
        for g, batcher in enumerate(self._batchers):
            if self._health is not None and not self._health.is_up(g):
                continue
            snapshot = batcher.index
            if self._quarantine.get(g) is snapshot:
                continue    # this exact state already failed to rebuild --
                #             don't hot-loop the failure; any ingest/delete
                #             produces a new snapshot and re-arms the group
            plan = None
            if self.merge_policy is not None:
                sel = self.merge_policy.select(snapshot)
                if sel is not None:
                    plan = {"kind": "merge", **sel}
            if plan is None:
                ratio = getattr(snapshot, "tombstone_ratio", 0.0)
                if ratio > self.threshold:
                    plan = {"kind": "compact", "tombstone_ratio": ratio}
            if plan is not None:
                plans.append((g, batcher, snapshot, plan))
        return plans

    def pending_plans(self) -> List[dict]:
        """Maintenance work currently due but not yet applied, one JSON-
        ready dict per group with work (``{"group": g, "kind": "merge" |
        "compact", ...}``) -- the ES ``number_of_pending_tasks`` field of
        ``cluster_health()``.  Planning only; never applies anything."""
        return [{"group": g, **plan} for g, _b, _s, plan in self._plan()]

    def _apply(self, g: int, batcher, snapshot, plan: dict) -> int:
        """Run one planned pass: rebuild outside the engine lock, install
        via CAS, record, commit.  Returns 1 if the pass was applied."""
        kind = plan["kind"]
        t0 = time.monotonic()
        try:
            if kind == "merge":
                with watch_region("maintenance.merge",
                                  sig=(plan["start"], plan["count"])):
                    rebuilt = snapshot.merge_segments(plan["start"],
                                                      plan["count"])
            else:
                with watch_region("maintenance.compact",
                                  sig=(int(getattr(snapshot, "n_ids", 0)),)):
                    rebuilt = snapshot.compact()      # outside the lock
        except Exception as exc:  # noqa: BLE001 - recorded, not fatal
            # a failing on-device rebuild (OOM, compile error) must not
            # kill maintenance for the healthy groups -- log it and
            # quarantine the snapshot instead of silently retrying the
            # same expensive failure every tick
            self._quarantine[g] = snapshot
            entry = {"group": g, "kind": kind, "error": repr(exc)}
            if kind == "compact":
                entry["tombstone_ratio"] = plan["tombstone_ratio"]
            self.failures.append(entry)
            self.metrics.counter("maintenance.failures", group=g).inc()
            return 0
        duration = time.monotonic() - t0
        try:
            swapped = batcher.swap_index(rebuilt, expected=snapshot)
        except RuntimeError:
            return 0                                  # engine closed mid-sweep
        if not swapped:
            # CAS miss: an ingest/delete raced the rebuild -- the next
            # sweep re-evaluates the fresh index
            return 0
        self._quarantine.pop(g, None)
        if kind == "merge":
            run = snapshot.segments[plan["start"]:plan["start"]
                                    + plan["count"]]
            reclaimed = sum(s.tombstones for s in run)
            self.merge_events.append({
                "group": g,
                "start": plan["start"],
                "count": plan["count"],
                "reason": plan["reason"],
                "reclaimed": reclaimed,
                "n_segments": len(rebuilt.segments),
                "duration_s": duration,
            })
            self.metrics.counter("maintenance.merges", group=g).inc()
            self.metrics.counter("maintenance.merge.reclaimed",
                                 group=g).inc(reclaimed)
            self.metrics.histogram(
                "maintenance.merge.duration_s").observe(duration)
        else:
            self.events.append({
                "group": g,
                "tombstone_ratio": plan["tombstone_ratio"],
                "n_ids": snapshot.n_ids,
                "duration_s": duration,
            })
            self.metrics.counter("maintenance.compactions", group=g).inc()
            self.metrics.histogram(
                "maintenance.compact.duration_s").observe(duration)
        self._commit(g, rebuilt)
        return 1

    def _commit(self, g: int, compacted) -> None:
        """Roll a commit point for the state that won the CAS (the ES
        flush after a merge).  ``compacted`` is OUR reference to the
        swapped-in index, so its (state, translog_seq) pair stays
        consistent even if a racing ingest has already moved the engine
        past it -- the racer's ops sit after ``translog_seq`` in the log
        and replay on top of this commit."""
        seq = getattr(compacted, "translog_seq", None)
        if self._store is None or seq is None:
            return
        try:
            self._store.commit(compacted, seq)
            self.commits += 1
        except Exception as exc:  # noqa: BLE001 - disk faults not fatal
            self.failures.append({"group": g, "commit_seq": seq,
                                  "error": repr(exc)})

    def probe_once(self) -> int:
        """Canary-probe every FAULTED group; readmit the ones that
        answer.  Returns groups re-admitted.  The canary goes through the
        group's real batcher (the honest path -- a group is healthy when
        it can serve, not when a side channel says so); routing never
        sees it because routing already avoids down groups.

        Canaries are tracked as in-flight futures: a FRESH canary gets a
        bounded ``probe_timeout_s`` window (so the deterministic
        ``probe_once()`` re-admits a responsive group in one call), but a
        canary that is still pending after that is left in flight and
        merely polled on later ticks -- a HUNG group costs its window
        once, not per tick, and can never starve the compaction sweeps
        sharing this thread.  Re-admission goes through
        ``HealthMap.readmit`` (atomic mark-up-unless-drained), so an
        operator drain recorded while the canary was in flight survives
        its success."""
        if self._health is None:
            return 0
        is_drained = getattr(self._health, "is_drained", lambda g: False)
        readmit = getattr(self._health, "readmit", self._health.mark_up)
        readmitted = 0
        for g, batcher in enumerate(self._batchers):
            if self._health.is_up(g) or is_drained(g):
                self._probes.pop(g, None)   # stale canary: nobody to admit
                continue
            fut = self._probes.get(g)
            if fut is None:
                try:
                    canary = np.ones((batcher.index.n_features,),
                                     np.float32)
                    fut = batcher.submit(canary)
                except Exception:  # noqa: BLE001 - closed/broken batcher
                    continue
                self._probes[g] = fut
                try:
                    fut.result(timeout=self.probe_timeout_s)
                except Exception:  # noqa: BLE001 - timeout OR canary error
                    pass
            if not fut.done():
                continue                    # hung: poll again next tick
            self._probes.pop(g, None)
            try:
                if fut.exception() is not None:
                    continue                # still faulty: steady state
            except BaseException:           # noqa: BLE001 - cancelled
                continue
            if readmit(g):
                readmitted += 1
                self.probe_events.append({"group": g})
                self.metrics.counter("maintenance.probe.readmits",
                                     group=g).inc()
        return readmitted

    def _run(self) -> None:
        tick = self.interval_s
        if self.probe:
            tick = min(tick, self.probe_interval_s)
        poll_at = probe_at = 0.0
        while not self._stop_evt.wait(tick):
            now = time.monotonic()
            if now >= poll_at:
                self.poll_once()
                poll_at = time.monotonic() + self.interval_s
            if self.probe and now >= probe_at:
                self.probe_once()
                probe_at = time.monotonic() + self.probe_interval_s
