"""Serving control plane over the doc-sharded data plane.

The paper's production claim is that a fulltext-engine-backed vector
database inherits Elasticsearch's robustness/stability/scalability.  The
data plane (:mod:`repro.dist`) reproduces the *index* side of that claim
-- doc-shards, replica copies, segments, tombstones.  This package is the
*cluster* side: the machinery that keeps serving when copies die, keeps
QPS scaling with replicas, and keeps segments healthy in the background.
Every component maps onto an ES concept:

===============================  ==========================================
this package                     Elasticsearch analogue
===============================  ==========================================
:class:`ClusterEngine`           the coordinating node's request routing:
(:mod:`~repro.cluster.router`)   R independent request batchers, one per
                                 replica group (R concurrent search
                                 programs on disjoint device sets);
                                 stream affinity = ``preference=
                                 <custom_string>`` session stickiness;
                                 least-loaded spill = adaptive replica
                                 selection.
:class:`HealthMap`               the cluster state's routing table (shard
(:mod:`~repro.cluster.health`)   copies ``STARTED``/``UNASSIGNED``);
                                 ``mark_down``/``mark_up`` = shard-failed
                                 / shard-started cluster-state updates,
                                 ``generation`` = cluster-state version.
failover resubmit                ES retrying a failed shard fetch on the
(in :class:`ClusterEngine`)      next copy of the same shard -- here the
                                 whole request replays on a surviving
                                 group and results stay bit-identical,
                                 because every group computes
                                 bit-identical results.
:class:`MaintenanceDaemon` +     Lucene's ConcurrentMergeScheduler +
:class:`TieredMergePolicy`       TieredMergePolicy: each sweep plans per
(:mod:`~repro.cluster.           replica group -- first a delete-heavy
maintenance`)                    segment rewrite (``index.merge.policy
                                 .deletes_pct_allowed``, consulting
                                 PER-SEGMENT deleted ratios), else a fold
                                 of ``merge_factor`` similar-sized sealed
                                 segments, else (only past the global
                                 tombstone threshold) the demoted full
                                 compact -- and applies concurrently
                                 across groups, off the query path,
                                 installing via the ``swap_index`` CAS so
                                 no in-flight query is dropped.  Given a
                                 durability store (:mod:`repro.store`),
                                 it also rolls a commit point after each
                                 pass and trims the replayed translog --
                                 the ES flush that follows a merge.
canary health probing            the master pinging an unresponsive node
(``MaintenanceDaemon.            and re-promoting its shard copies once
probe_once``)                    it answers: downed groups get a canary
                                 query each tick and ``mark_up`` when it
                                 succeeds -- re-admission without manual
                                 intervention.
``ClusterEngine.restore_group``  replica recovery from the primary's
                                 translog: a group whose MEMORY is gone
                                 rebuilds from commit point + translog
                                 replay (:mod:`repro.store`) onto its own
                                 device column and rejoins, bit-identical
                                 to its surviving siblings.
===============================  ==========================================

The data-plane hooks these build on live in
:class:`repro.dist.shard_index.ShardedVectorIndex`: ``replica_group(g)``
(a replica column as an independent 1-D index -- group addressability),
``search(..., live_groups=...)`` (the health-masked merge), and
``tombstone_ratio`` / exact-df deletes (the maintenance trigger).
"""

from repro.cluster.health import HealthMap
from repro.cluster.maintenance import MaintenanceDaemon, TieredMergePolicy
from repro.cluster.router import ClusterEngine

__all__ = ["ClusterEngine", "HealthMap", "MaintenanceDaemon",
           "TieredMergePolicy"]
